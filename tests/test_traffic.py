"""Deterministic serving-traffic harness tests: seeded trace
reproducibility, hand-computed SLO arithmetic, full-simulation
determinism under the virtual clock with the tiers and the online
compiler churning, token-identity of every request against an offline
single-request run, token-exact preempt/resume (dense + paged, jnp +
pallas-interpret), fake-clock timing regression, and the ``stats()``
schema snapshot."""

import json
import math

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import memcom
from repro.data import SyntheticVocab
from repro.models import transformer as tfm
from repro.serving import (
    Request,
    ServingEngine,
    TrafficConfig,
    VirtualClock,
    generate_trace,
    materialize_prefix,
    slo_metrics,
)
from repro.serving.clock import DEFAULT_COSTS
from repro.serving.scheduler import Scheduler
from repro.serving.traffic import zipf_weights


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm-135m")
    params = tfm.init_params(cfg, 0)
    mc = memcom.init_memcom(cfg, params, 1)
    return cfg, params, mc


#: the churn scenario every simulation test uses: catalog (5 tasks)
#: exceeds prefix_capacity (2) and host_capacity (2), so demote/spill/
#: promote and online compiles all fire; two priority classes at a rate
#: hot enough to queue, so preemption pressure exists too
CHURN = TrafficConfig(num_tasks=5, num_requests=12, context_tokens=24,
                      rate_rps=300.0, priority_classes=2)


def _churn_engine(cfg, params, mc, disk_dir, **kw):
    m = cfg.memcom.num_memory_tokens
    base = dict(slots=2, max_len=m + 32, compressor=mc,
                compile_token_budget=8, prefix_capacity=2,
                host_capacity=2, disk_dir=str(disk_dir),
                promote_layer_budget=1, clock=VirtualClock(),
                priority_aging_s=0.05)
    base.update(kw)
    return ServingEngine(cfg, params, **base)


def _simulate(cfg, params, mc, disk_dir, seed=0):
    """One full churn simulation; returns (slo metrics, stats, tokens
    in trace order)."""
    trace = generate_trace(CHURN, seed)
    eng = _churn_engine(cfg, params, mc, disk_dir)
    out = eng.serve(list(trace.requests))
    metrics = slo_metrics(eng.request_log, slo_ttft_s=0.02,
                          gap_samples=eng.gap_samples)
    tokens = [list(out[r.uid]) for r in trace.requests]
    return metrics, eng.stats(), tokens


# ---------------------------------------------------------------------------
# Trace generation
# ---------------------------------------------------------------------------


def _trace_fingerprint(trace):
    return [(r.arrival_s, r.tokens.tobytes(), r.max_new, r.priority,
             r.raw_shots.tobytes()) for r in trace.requests]


@pytest.mark.parametrize("process", ["poisson", "onoff"])
def test_trace_reproducible(process):
    """Same (config, seed) -> byte-identical trace; a different seed
    moves it."""
    cfg = TrafficConfig(num_tasks=4, num_requests=20, context_tokens=16,
                        process=process, priority_classes=2)
    a, b = generate_trace(cfg, 7), generate_trace(cfg, 7)
    assert _trace_fingerprint(a) == _trace_fingerprint(b)
    assert a.task_ids == b.task_ids
    c = generate_trace(cfg, 8)
    assert _trace_fingerprint(a) != _trace_fingerprint(c)


def test_arrivals_sorted_and_positive():
    for process in ("poisson", "onoff"):
        cfg = TrafficConfig(num_tasks=2, num_requests=30, context_tokens=16,
                            process=process)
        ts = [r.arrival_s for r in generate_trace(cfg, 1).requests]
        assert len(ts) == 30
        assert all(t > 0 for t in ts)
        assert ts == sorted(ts)


def test_zipf_popularity_skew():
    w = zipf_weights(8, 1.2)
    assert math.isclose(float(w.sum()), 1.0)
    assert all(w[i] > w[i + 1] for i in range(7))  # rank 0 is the head
    cfg = TrafficConfig(num_tasks=8, num_requests=200, context_tokens=16,
                        zipf_alpha=1.2)
    ids = generate_trace(cfg, 3).task_ids
    counts = np.bincount(ids, minlength=8)
    assert counts[0] == counts.max()  # the head actually dominates
    assert len(set(ids)) > 1          # and the tail exists


def test_catalog_tasks_distinct():
    cfg = TrafficConfig(num_tasks=6, num_requests=1, context_tokens=16)
    cat = generate_trace(cfg, 0).catalog
    assert len({c.tobytes() for c in cat}) == 6


def test_traffic_config_validation():
    with pytest.raises(ValueError):
        TrafficConfig(process="uniform")
    with pytest.raises(ValueError):
        TrafficConfig(rate_rps=0.0)
    with pytest.raises(ValueError):
        TrafficConfig(priority_classes=2, priority_weights=(1.0,))


# ---------------------------------------------------------------------------
# SLO arithmetic (hand-computed micro-trace)
# ---------------------------------------------------------------------------


def test_slo_metrics_hand_computed():
    """Three completed requests + one in flight, checked against the
    documented percentile formula (index = (n-1)*q/100, linear
    interpolation) and goodput/throughput by hand."""
    log = {
        1: {"priority": 0, "arrival_s": 0.0, "first_token_s": 0.01,
            "finish_s": 0.02, "tokens": 2, "preemptions": 0},
        2: {"priority": 0, "arrival_s": 0.1, "first_token_s": 0.15,
            "finish_s": 0.20, "tokens": 3, "preemptions": 1},
        3: {"priority": 1, "arrival_s": 0.2, "first_token_s": 0.30,
            "finish_s": 0.40, "tokens": 4, "preemptions": 0},
        4: {"priority": 1, "arrival_s": 0.3, "first_token_s": None,
            "finish_s": None, "tokens": 0, "preemptions": 0},
    }
    m = slo_metrics(log, slo_ttft_s=0.05, devices=2,
                    gap_samples=[0.001, 0.002, 0.003])
    assert m["requests"] == 4 and m["completed"] == 3
    # ttfts sorted: [0.01, 0.05, 0.10]; p50 = middle, p99 interpolates
    # between index 1.98's neighbours: 0.05 + 0.98 * (0.10 - 0.05)
    assert math.isclose(m["ttft_p50_s"], 0.05)
    assert math.isclose(m["ttft_p99_s"], 0.05 + 0.98 * 0.05)
    # latencies sorted: [0.02, 0.10, 0.20]
    assert math.isclose(m["latency_p50_s"], 0.10)
    # makespan: first arrival 0.0 -> last finish 0.4
    assert math.isclose(m["duration_s"], 0.4)
    # TTFTs 0.01 and 0.05 meet the 0.05 SLO; 0.10 misses
    assert m["slo_attained"] == 2
    assert math.isclose(m["goodput_rps"], 2 / 0.4)
    assert math.isclose(m["offered_rps"], 4 / 0.4)
    assert m["tokens_generated"] == 9
    assert math.isclose(m["tokens_per_s_per_device"], 9 / 0.4 / 2)
    # decode-gap aggregates are bucket-derived (registry Histogram, 1-2-5
    # ladder): [0.001, 0.002, 0.003] land in the le=0.001/0.002/0.005
    # buckets.  p99: rank 2.97 falls in (0.002, 0.005] with 2 below →
    # 0.002 + 0.003 * 0.97; p50: rank 1.5 in (0.001, 0.002] with 1 below.
    assert math.isclose(m["decode_gap_p99_s"], 0.002 + 0.003 * 0.97)
    assert math.isclose(m["decode_gap_p50_s"], 0.001 + 0.001 * 0.5)
    hist = m["decode_gap_hist"]
    assert hist["count"] == 3 and math.isclose(hist["sum"], 0.006)
    assert sum(hist["counts"]) == 3 and hist["le"][-1] == "+Inf"
    assert m["preemptions"] == 1
    c0, c1 = m["per_class"]["0"], m["per_class"]["1"]
    assert c0["requests"] == 2 and c0["completed"] == 2
    assert c0["slo_attained"] == 2 and c0["preemptions"] == 1
    assert c1["requests"] == 2 and c1["completed"] == 1
    assert c1["slo_attained"] == 0


def test_slo_metrics_empty_log():
    m = slo_metrics({}, slo_ttft_s=0.1)
    assert m["requests"] == 0 and m["completed"] == 0
    assert m["goodput_rps"] == 0.0 and m["ttft_p99_s"] == 0.0
    assert m["per_class"] == {}


def test_slo_metrics_no_completions_reports_zero_rates():
    """In-flight requests but zero completions: there is no makespan, so
    the rates must read 0.0 — not the astronomical figures a sentinel
    divisor would produce in serving_bench.json."""
    log = {1: {"priority": 0, "arrival_s": 0.0, "first_token_s": None,
               "finish_s": None, "tokens": 0, "preemptions": 0}}
    m = slo_metrics(log, slo_ttft_s=0.1)
    assert m["requests"] == 1 and m["completed"] == 0
    assert m["duration_s"] == 0.0
    assert m["offered_rps"] == 0.0 and m["goodput_rps"] == 0.0
    assert m["tokens_per_s_per_device"] == 0.0


# ---------------------------------------------------------------------------
# Full-simulation determinism + churn
# ---------------------------------------------------------------------------


def test_simulation_deterministic_with_churn(setup, tmp_path):
    """Two same-seed runs (fresh engines, clocks and disk dirs) produce
    byte-identical SLO JSON and identical per-request tokens — while the
    scenario actually churns: online compiles, tier demotions and
    preemptions all fire.  A stale disk dir would break this (run 2
    would promote run 1's shards instead of compiling), which is why
    every run gets its own directory."""
    m1, s1, t1 = _simulate(*setup, tmp_path / "a")
    m2, s2, t2 = _simulate(*setup, tmp_path / "b")
    assert json.dumps(m1, sort_keys=True) == json.dumps(m2, sort_keys=True)
    assert t1 == t2
    assert s1["engine"] == s2["engine"]
    assert s1["compiler"]["jobs"] > 0          # online compiles fired
    assert s1["prefix_tiers"]["demotes"] > 0   # tier churn fired
    assert m1["completed"] == m1["requests"] == CHURN.num_requests
    assert m1["preemptions"] > 0               # priority pressure fired


def test_different_seed_changes_simulation(setup, tmp_path):
    m1, _, _ = _simulate(*setup, tmp_path / "a", seed=0)
    m2, _, _ = _simulate(*setup, tmp_path / "b", seed=1)
    assert json.dumps(m1, sort_keys=True) != json.dumps(m2, sort_keys=True)


def test_churn_tokens_match_offline_reference(setup, tmp_path):
    """Every request served under load (queueing, preemption, tier
    churn, budget-chunked compiles) emits exactly the tokens an offline
    engine produces serving it alone against an unbounded store: the
    scheduling machinery moves *when* work happens, never *what* comes
    out."""
    cfg, params, mc = setup
    _, _, tokens = _simulate(cfg, params, mc, tmp_path / "sim")
    trace = generate_trace(CHURN, 0)

    m = cfg.memcom.num_memory_tokens
    ref = ServingEngine(cfg, params, slots=1, max_len=m + 32,
                        compressor=mc)  # unbounded store, no tiers
    for i, r in enumerate(trace.requests):
        solo = Request(tokens=r.tokens, max_new=r.max_new,
                       raw_shots=r.raw_shots)
        out = ref.serve([solo])
        assert tokens[i] == list(out[solo.uid]), f"request {i} diverged"


# ---------------------------------------------------------------------------
# Preempt/resume token-exactness (dense + paged, jnp + pallas-interpret)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("layout", ["dense", "paged"])
@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_preempt_resume_token_exact(setup, rng, layout, impl):
    """A long decode preempted mid-stream by an urgent request and
    resumed later emits exactly the tokens of an uncontended run — the
    resume re-prefills prompt+emitted, so greedy decode continues from
    the identical state."""
    cfg, params, mc = setup
    m = cfg.memcom.num_memory_tokens
    shots = rng.integers(4, cfg.vocab_size, 24).astype(np.int32)
    prompt = rng.integers(4, cfg.vocab_size, 5).astype(np.int32)
    prefix, _ = memcom.compress(mc, cfg, np.asarray(shots)[None])
    kv = materialize_prefix(params, cfg, prefix)

    def build():
        eng = ServingEngine(cfg, params, slots=1, max_len=m + 32,
                            kv_layout=layout, impl=impl,
                            clock=VirtualClock())
        eng.add_prefix("task", kv)
        return eng

    solo = build()
    ref = solo.serve([Request(tokens=prompt, max_new=10, prefix="task")])
    ref = list(next(iter(ref.values())))

    eng = build()
    long = Request(tokens=prompt, max_new=10, prefix="task",
                   priority=1, arrival_s=0.0)
    urgent = Request(tokens=prompt[:3], max_new=2, prefix="task",
                     priority=0, arrival_s=0.004)
    out = eng.serve([long, urgent])
    es = eng.stats()["engine"]
    assert es["preemptions"] == 1
    assert es["preempted_tokens_refilled"] > 0
    assert list(out[long.uid]) == ref


def test_preemption_never_evicts_just_admitted_slot(setup):
    """Regression: serve() runs the priority-preemption check *after*
    admit() in the same loop iteration, while it still holds that
    admit's (slot, request) pairs un-prefilled.  With aging enabled a
    base-class-1 request can win admission over a pending class-0 one
    and immediately qualify as a victim (preemption compares base
    classes) — evicting it there would strand a stale pair that serve()
    then prefills into a slot the scheduler has re-assigned.  The
    just-admitted slots are therefore passed as ``protected`` and must
    never be picked."""
    cfg, params, _ = setup
    m = cfg.memcom.num_memory_tokens
    clock = VirtualClock()
    eng = ServingEngine(cfg, params, slots=1, max_len=m + 32, clock=clock,
                        priority_aging_s=0.005)
    sched = Scheduler(1, clock=clock, aging_interval_s=0.005)
    low = Request(tokens=np.array([5, 6], np.int32), max_new=4, priority=1)
    sched.submit(low)
    clock.advance(0.01)  # low ages to effective class 0
    hi = Request(tokens=np.array([7], np.int32), max_new=2, priority=0)
    sched.submit(hi)
    [(slot, seated)] = sched.admit()
    assert seated is low  # aged + earlier arrival: wins admission over hi
    # the serve loop protects the batch it just admitted: no victim
    assert eng._preempt_for_priority(sched, None, protected={slot}) == []
    assert sched.request_in(slot) is low
    assert sched.preemptions == 0 and eng.stats()["engine"]["preemptions"] == 0
    # a later iteration (nothing freshly admitted) may preempt it
    eng.request_log[low.uid] = {"preemptions": 0}
    eng._preempt_for_priority(sched, None)
    assert sched.preemptions == 1


def test_autotune_grow_caps_at_8x_configured(setup):
    """The grow path clamps each budget to 8x its *configured* value —
    after shrinks land a budget off the power-of-two ladder, plain
    doubling would overshoot to just under 16x (e.g. configured 5:
    2 -> 4 -> 8 -> 16 -> 32 -> 64 = 12.8x)."""
    cfg, params, _ = setup
    m = cfg.memcom.num_memory_tokens
    eng = ServingEngine(cfg, params, slots=1, max_len=m + 32,
                        clock=VirtualClock(), autotune_budgets=True,
                        target_decode_gap_s=1.0, compile_token_budget=5,
                        promote_layer_budget=3, autotune_interval=1)
    # as if earlier overshoot windows had shrunk both budgets
    eng.compile_token_budget, eng.promote_layer_budget = 2, 1
    for _ in range(10):
        eng._gap_window[:] = [0.0]  # deep undershoot -> grow
        eng._autotune_step()
    assert eng.compile_token_budget == 5 * 8
    assert eng.promote_layer_budget == 3 * 8


# ---------------------------------------------------------------------------
# Fake-clock timing determinism (the perf_counter testability fix)
# ---------------------------------------------------------------------------


def test_stats_timing_deterministic_under_fake_clock(setup, tmp_path):
    """``decode_time_s`` and the gap fields come from the injected
    clock, not ``time.perf_counter()``: under a VirtualClock they are
    exact functions of the cost model, identical across runs."""
    cfg, params, mc = setup

    def run(sub):
        eng = _churn_engine(cfg, params, mc, tmp_path / sub)
        eng.serve(list(generate_trace(CHURN, 0).requests))
        return eng.stats()["engine"], eng.gap_samples

    e1, g1 = run("a")
    e2, g2 = run("b")
    assert e1 == e2
    assert g1 == g2
    # decode time is exactly decode_steps x the decode-step charge
    assert math.isclose(e1["decode_time_s"],
                        e1["decode_steps"] * DEFAULT_COSTS["decode_step"])
    assert e1["decode_gap_p99_s"] == float(np.percentile(g1, 99))


# ---------------------------------------------------------------------------
# stats() schema snapshot
# ---------------------------------------------------------------------------

GOLDEN_ENGINE_KEYS = sorted([
    "prefills", "decode_steps", "tokens_generated",
    "decode_steps_during_compile", "compile_chunks_interleaved",
    "decode_steps_during_promote", "promote_steps_interleaved",
    "decode_gap_max_s", "decode_gap_sum_s", "decode_gaps",
    "decode_time_s", "decode_gap_p50_s", "decode_gap_p99_s",
    "preemptions", "preempted_tokens_refilled",
    "autotune_shrinks", "autotune_grows",
    # PR 7: fused step + speculative decoding
    "fused_steps", "fused_chunks", "fused_prefill_chunks",
    "fused_prefill_tokens", "fused_compile_chunks", "spec_rounds",
    "draft_proposed", "draft_accepted", "accept_rate", "jit_compiles",
])
GOLDEN_TIER_KEYS = sorted([
    "hbm_hits", "host_promotes", "disk_loads", "demotes", "spills",
    "promote_bytes", "promote_chunks", "host_drops", "hbm_resident",
    "host_resident", "disk_resident", "promotions_in_flight",
])
GOLDEN_BUDGET_KEYS = sorted([
    "compile_token_budget", "promote_layer_budget", "autotune",
])
GOLDEN_POOL_KEYS = sorted([
    "num_blocks", "block_size", "blocks_used", "blocks_free",
])


def test_stats_schema_golden(setup, tmp_path):
    """The full ``stats()`` surface for a paged+tiered+compiling engine.
    A key rename or removal here breaks the serving bench, the traffic
    harness and the launcher's ``--stats`` consumers — this snapshot
    makes that an explicit decision instead of a silent drift."""
    cfg, params, mc = setup
    eng = _churn_engine(cfg, params, mc, tmp_path, kv_layout="paged")
    eng.serve(list(generate_trace(CHURN, 0).requests))
    s = eng.stats()
    assert sorted(s.keys()) == ["budgets", "compiler", "engine", "pool",
                                "prefix_store", "prefix_tiers"]
    assert sorted(s["engine"].keys()) == GOLDEN_ENGINE_KEYS
    assert sorted(s["prefix_store"].keys()) == sorted(
        ["hits", "misses", "puts", "evictions"])
    assert sorted(s["compiler"].keys()) == sorted(
        ["jobs", "deduped", "chunks", "tokens", "compiled"])
    assert sorted(s["budgets"].keys()) == GOLDEN_BUDGET_KEYS
    assert sorted(s["prefix_tiers"].keys()) == GOLDEN_TIER_KEYS
    assert sorted(s["pool"].keys()) == GOLDEN_POOL_KEYS
    # every counter JSON-serializes (the bench writes stats verbatim)
    json.dumps(s)
