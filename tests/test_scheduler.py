"""Scheduler unit tests: FIFO admission, per-slot termination, refill,
priority classes, anti-starvation aging, and preempt/resume bookkeeping
(hypothesis property tests live in test_scheduler_properties.py)."""

import numpy as np
import pytest

from repro.serving.scheduler import Request, Scheduler


def _req(n=4, **kw):
    kw.setdefault("max_new", 3)
    return Request(tokens=np.arange(n, dtype=np.int32), **kw)


def test_fifo_admission_into_free_slots():
    s = Scheduler(2)
    r1, r2, r3 = _req(), _req(), _req()
    for r in (r1, r2, r3):
        s.submit(r)
    seated = s.admit()
    assert [(slot, r.uid) for slot, r in seated] == [(0, r1.uid), (1, r2.uid)]
    assert s.pending == 1 and s.free_slots() == []
    assert s.admit() == []  # no free slot -> nothing admitted


def test_per_slot_budget_and_stop_token():
    s = Scheduler(2)
    a = _req(max_new=2)
    b = _req(max_new=10, stop_token=99)
    s.submit(a), s.submit(b)
    s.admit()
    # slot 0 finishes by budget; slot 1 keeps going past it
    assert s.record_token(0, 7) is False
    assert s.record_token(1, 7) is False
    assert s.record_token(0, 8) is True
    assert s.record_token(1, 8) is False
    req, toks = s.finish(0)
    assert req.uid == a.uid
    np.testing.assert_array_equal(toks, [7, 8])
    # slot 1 finishes by its own stop token, which is included in output
    assert s.record_token(1, 99) is True
    _, toks = s.finish(1)
    np.testing.assert_array_equal(toks, [7, 8, 99])


def test_refill_after_finish():
    s = Scheduler(1)
    a, b = _req(max_new=1), _req(max_new=1)
    s.submit(a), s.submit(b)
    assert [slot for slot, _ in s.admit()] == [0]
    s.record_token(0, 1)
    s.finish(0)
    seated = s.admit()  # freed slot picks up the queued request
    assert [(slot, r.uid) for slot, r in seated] == [(0, b.uid)]
    assert s.has_work()
    s.record_token(0, 2)
    s.finish(0)
    assert not s.has_work()


def test_request_validation():
    with pytest.raises(ValueError):
        Request(tokens=np.arange(3), max_new=0)
    r = Request(tokens=[[1, 2, 3]], max_new=1)  # flattened + int32
    assert r.tokens.dtype == np.int32 and r.tokens.shape == (3,)


def test_raw_shots_content_addressed_name():
    shots = np.arange(5, 25, dtype=np.int32)
    a = Request(tokens=[1], max_new=1, raw_shots=shots)
    b = Request(tokens=[2], max_new=1, raw_shots=shots.copy())
    c = Request(tokens=[3], max_new=1, raw_shots=shots[::-1].copy())
    assert a.prefix == b.prefix  # same bytes -> one compile, one entry
    assert a.prefix != c.prefix
    named = Request(tokens=[4], max_new=1, raw_shots=shots, prefix="mine")
    assert named.prefix == "mine"  # explicit name wins
    with pytest.raises(ValueError):
        Request(tokens=[1], max_new=1, raw_shots=np.empty((0,), np.int32))


def test_park_wake_preserves_fifo_order():
    """waiting_on_prefix requests wake to the *head* of the queue in
    their submission order — a later plain request never overtakes them."""
    s = Scheduler(2)
    w1 = _req(prefix="cold")
    w2 = _req(prefix="cold")
    later = _req()
    s.park(w1), s.park(w2)
    s.submit(later)
    assert s.has_work() and s.num_waiting == 2
    assert s.waiting_names() == ("cold",)
    assert [r.uid for r in s.waiting_on("cold")] == [w1.uid, w2.uid]
    woken = s.wake("cold")
    assert [r.uid for r in woken] == [w1.uid, w2.uid]
    assert s.num_waiting == 0
    seated = s.admit()
    assert [r.uid for _, r in seated] == [w1.uid, w2.uid]  # before `later`
    assert s.pending == 1
    assert s.wake("cold") == []  # idempotent


def test_wake_never_overtakes_earlier_arrivals():
    """Two compiles finishing out of arrival order: whichever wakes
    second still lands at its original position — R's requests (arrived
    later) never overtake P's, and vice versa."""
    for first, second in (("P", "R"), ("R", "P")):
        s = Scheduler(1)
        p1, p2 = _req(prefix="P"), _req(prefix="P")
        r1, r2 = _req(prefix="R"), _req(prefix="R")
        s.park(p1), s.park(p2), s.park(r1), s.park(r2)
        s.wake(first), s.wake(second)
        assert [r.uid for r in s._queue] == [p1.uid, p2.uid, r1.uid, r2.uid]


def test_referenced_prefixes_spans_all_stages():
    s = Scheduler(1)
    s.park(_req(prefix="waiting"))
    s.submit(_req(prefix="queued"))
    s.submit(_req(prefix="running"))
    s.submit(_req())  # no prefix -> not referenced
    # admit seats the first queued request ("queued" enters a slot)
    s.admit()
    assert s.referenced_prefixes() == {"waiting", "queued", "running"}


# ---------------------------------------------------------------------------
# Priority classes, aging, preemption
# ---------------------------------------------------------------------------


class FakeClock:
    """Settable clock: ``clk.t = ...`` is the whole API."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_priority_admission_order():
    """Lower class admits first; a single class stays plain FIFO."""
    s = Scheduler(3)
    low, urgent, mid = _req(priority=2), _req(priority=0), _req(priority=1)
    for r in (low, urgent, mid):
        s.submit(r)
    assert [r.uid for _, r in s.admit()] == [urgent.uid, mid.uid, low.uid]


def test_fifo_within_priority_class():
    s = Scheduler(2)
    a, b = _req(priority=1), _req(priority=1)
    s.submit(a), s.submit(b)
    assert [r.uid for _, r in s.admit()] == [a.uid, b.uid]
    assert s.best_queued() is None


def test_aging_promotes_long_waiting_request():
    """After waiting 2 x aging_interval, a class-2 request outranks a
    freshly arrived class-1 request — starvation is bounded."""
    clk = FakeClock()
    s = Scheduler(1, clock=clk, aging_interval_s=1.0)
    old_low = _req(priority=2)
    s.submit(old_low)
    assert s.effective_class(old_low) == 2
    clk.t = 2.5
    fresh_mid = _req(priority=1)
    s.submit(fresh_mid)
    assert s.effective_class(old_low) == 0  # aged two classes
    assert s.effective_class(fresh_mid) == 1
    assert [r.uid for _, r in s.admit()] == [old_low.uid]


def test_aging_disabled_without_interval():
    clk = FakeClock()
    s = Scheduler(1, clock=clk)
    r = _req(priority=3)
    s.submit(r)
    clk.t = 1e9
    assert s.effective_class(r) == 3
    with pytest.raises(ValueError):
        Scheduler(1, aging_interval_s=0.0)


def test_preempt_resume_bookkeeping():
    """Preemption stashes the emitted tokens and requeues at the original
    arrival position; re-admission restores the stash and the budget
    keeps counting against the original max_new."""
    s = Scheduler(1)
    a = _req(max_new=5, priority=1)
    s.submit(a)
    s.admit()
    s.record_token(0, 11), s.record_token(0, 12)
    b = _req(max_new=1, priority=0)
    s.submit(b)
    assert s.best_queued().uid == b.uid  # class 0 outranks the runner
    victim = s.preempt(0)
    assert victim.uid == a.uid and s.preemptions == 1
    assert s.free_slots() == [0]
    assert s.resume_len(a.uid) == 2
    # the urgent request runs first; the victim waits at its arrival slot
    assert [r.uid for _, r in s.admit()] == [b.uid]
    s.record_token(0, 99)
    s.finish(0)
    [(slot, r)] = s.admit()
    assert r.uid == a.uid
    np.testing.assert_array_equal(s.emitted_tokens(slot), [11, 12])
    assert s.resume_len(a.uid) == 0  # stash consumed on re-admission
    assert s.record_token(slot, 13) is False
    assert s.record_token(slot, 14) is False
    assert s.record_token(slot, 15) is True  # 5 tokens total, not 5 more
    _, toks = s.finish(slot)
    np.testing.assert_array_equal(toks, [11, 12, 13, 14, 15])


def test_preempted_request_keeps_arrival_order():
    """A preempted request re-enters *ahead* of same-class requests that
    arrived after it — eviction does not cost it its queue position."""
    s = Scheduler(1)
    first, later = _req(max_new=4), _req(max_new=4)
    s.submit(first)
    s.admit()
    s.record_token(0, 1)
    s.submit(later)
    s.preempt(0)
    assert [r.uid for r in s._queue] == [first.uid, later.uid]
    [(slot, r)] = s.admit()
    assert r.uid == first.uid
    np.testing.assert_array_equal(s.emitted_tokens(slot), [1])
