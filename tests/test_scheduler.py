"""Scheduler unit tests: FIFO admission, per-slot termination, refill."""

import numpy as np
import pytest

from repro.serving.scheduler import Request, Scheduler


def _req(n=4, **kw):
    kw.setdefault("max_new", 3)
    return Request(tokens=np.arange(n, dtype=np.int32), **kw)


def test_fifo_admission_into_free_slots():
    s = Scheduler(2)
    r1, r2, r3 = _req(), _req(), _req()
    for r in (r1, r2, r3):
        s.submit(r)
    seated = s.admit()
    assert [(slot, r.uid) for slot, r in seated] == [(0, r1.uid), (1, r2.uid)]
    assert s.pending == 1 and s.free_slots() == []
    assert s.admit() == []  # no free slot -> nothing admitted


def test_per_slot_budget_and_stop_token():
    s = Scheduler(2)
    a = _req(max_new=2)
    b = _req(max_new=10, stop_token=99)
    s.submit(a), s.submit(b)
    s.admit()
    # slot 0 finishes by budget; slot 1 keeps going past it
    assert s.record_token(0, 7) is False
    assert s.record_token(1, 7) is False
    assert s.record_token(0, 8) is True
    assert s.record_token(1, 8) is False
    req, toks = s.finish(0)
    assert req.uid == a.uid
    np.testing.assert_array_equal(toks, [7, 8])
    # slot 1 finishes by its own stop token, which is included in output
    assert s.record_token(1, 99) is True
    _, toks = s.finish(1)
    np.testing.assert_array_equal(toks, [7, 8, 99])


def test_refill_after_finish():
    s = Scheduler(1)
    a, b = _req(max_new=1), _req(max_new=1)
    s.submit(a), s.submit(b)
    assert [slot for slot, _ in s.admit()] == [0]
    s.record_token(0, 1)
    s.finish(0)
    seated = s.admit()  # freed slot picks up the queued request
    assert [(slot, r.uid) for slot, r in seated] == [(0, b.uid)]
    assert s.has_work()
    s.record_token(0, 2)
    s.finish(0)
    assert not s.has_work()


def test_request_validation():
    with pytest.raises(ValueError):
        Request(tokens=np.arange(3), max_new=0)
    r = Request(tokens=[[1, 2, 3]], max_new=1)  # flattened + int32
    assert r.tokens.dtype == np.int32 and r.tokens.shape == (3,)
