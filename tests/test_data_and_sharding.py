"""Data pipeline + sharding-rules unit tests, and a mini end-to-end
sharded lower/compile on an 8-device placeholder topology (subprocess,
so the main test process keeps its single real device)."""

import json
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.data import (
    ICLTaskSpec, Prefetcher, PretrainStream, SyntheticVocab,
    build_manyshot_prompt, make_episode,
)
from repro.data.pipeline import host_slice
from repro.sharding.rules import BASELINE_RULES, FSDP_RULES, spec_for


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_prompt_builder_budget_and_balance(rng):
    v = SyntheticVocab(num_keys=32, num_labels=8)
    task = ICLTaskSpec(vocab=v, num_labels=8, keys_per_label=4)
    ep = make_episode(task, rng)
    budget = 65
    prompt = build_manyshot_prompt(task, ep, rng, budget)
    assert len(prompt) <= budget
    # class balance: round-robin ⇒ per-label shot counts differ by ≤ 1
    labels = prompt[3::4] - v.label_base
    counts = np.bincount(labels, minlength=8)
    assert counts.max() - counts.min() <= 1
    # structure: [SEP key ARROW label] repeated
    assert (prompt[0::4] == v.SEP).all()
    assert (prompt[2::4] == v.ARROW).all()


def test_prompt_budget_monotone(rng):
    """Fewer-shots baseline: smaller budget ⇒ prefix of the shot sequence
    (same construction, same RNG), the paper's §5 baseline definition."""
    v = SyntheticVocab(num_keys=32, num_labels=8)
    task = ICLTaskSpec(vocab=v, num_labels=8, keys_per_label=4)
    ep = make_episode(task, rng)
    big = build_manyshot_prompt(task, ep, np.random.default_rng(5), 64)
    small = build_manyshot_prompt(task, ep, np.random.default_rng(5), 32)
    assert len(small) <= 32 < len(big) <= 64
    np.testing.assert_array_equal(big[: len(small)], small)


def test_stream_source_target_split():
    s = PretrainStream(SyntheticVocab(), batch=3, seq_len=64,
                       split_choices=(40, 48), seed=1)
    b = s.batch_at(0)
    assert b["source"].shape[1] + b["target"].shape[1] == 64
    assert b["source"].shape[1] in (40, 48)


def test_prefetcher_orders_and_stops():
    seen = []
    pf = Prefetcher(lambda i: {"i": i}, start_step=5, depth=2)
    for _ in range(4):
        step, item = pf.get()
        seen.append(step)
        assert item["i"] == step
    pf.stop()
    assert seen == [5, 6, 7, 8]


def test_host_slice_partitions():
    sl = [host_slice(32, h, 4) for h in range(4)]
    idx = np.arange(32)
    got = np.concatenate([idx[s] for s in sl])
    np.testing.assert_array_equal(got, idx)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------


class _StubMesh:
    """spec_for only reads axis_names and shape — a stub stands in for the
    production 16×16 mesh without needing 256 devices."""

    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_spec_for_divisibility():
    mesh = _StubMesh()
    # divisible → sharded; non-divisible → dropped to replication
    spec = spec_for((32, 64), ("vocab", "embed"), mesh, BASELINE_RULES)
    assert spec == P("model", None)
    spec = spec_for((17, 64), ("vocab", "embed"), mesh, BASELINE_RULES)
    assert spec == P(None, None)


def test_spec_for_no_axis_reuse():
    mesh = _StubMesh()
    spec = spec_for((32, 32), ("heads", "ff"), mesh, BASELINE_RULES)
    # both want "model"; only the first may take it
    assert spec == P("model", None)


def test_fsdp_rules_shard_embed_over_data():
    mesh = _StubMesh()
    spec = spec_for((32, 32), ("embed", "heads"), mesh, FSDP_RULES)
    # newer jax canonicalizes singleton axis tuples to bare names
    assert spec in (P(("data",), "model"), P("data", "model"))


def test_granite_oddballs_drop_to_replication():
    """granite: 40 experts and 49155-row vocab don't divide 16 — the
    rules must degrade those dims to replication, not crash."""
    mesh = _StubMesh()
    spec = spec_for((40, 1536, 512), ("expert", "embed", "ff"), mesh,
                    FSDP_RULES)
    assert spec in (P(None, ("data",), "model"), P(None, "data", "model"))
    spec = spec_for((49155, 1536), ("vocab", "embed"), mesh, FSDP_RULES)
    assert spec in (P(None, ("data",)), P(None, "data"))


MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from repro.configs import get_smoke_config
    from repro.launch.steps import (build_memcom_train_step, memcom_shardings,
                                    param_shardings, _with_shardings,
                                    act_sharding_for, opt_shardings)
    from repro.core import memcom
    from repro.optim import AdamW
    from repro.sharding.ctx import act_sharding
    import jax.numpy as jnp

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_smoke_config("smollm-135m").replace(
        d_model=128, num_heads=4, num_kv_heads=2, d_ff=256)
    step, _ = build_memcom_train_step(cfg, phase=1)
    mc_sh, mc_abs = memcom_shardings(cfg, mesh)
    tgt_sh, tgt_abs = param_shardings(cfg, mesh)
    mask = memcom.trainable_mask(mc_abs, 1)
    opt_abs = jax.eval_shape(AdamW(lr=0.0, mask=mask).init, mc_abs)
    opt_sh = opt_shardings(opt_abs, mc_sh, mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P
    batch = {
        "source": jax.ShapeDtypeStruct((8, 32), jnp.int32,
            sharding=NamedSharding(mesh, P("data", None))),
        "target": jax.ShapeDtypeStruct((8, 16), jnp.int32,
            sharding=NamedSharding(mesh, P("data", None))),
    }
    args = (_with_shardings(mc_abs, mc_sh), _with_shardings(opt_abs, opt_sh),
            _with_shardings(tgt_abs, tgt_sh), batch)
    with act_sharding(act_sharding_for(mesh, cfg, 8, 32)):
        compiled = jax.jit(step, donate_argnums=(0, 1)).lower(*args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returned [dict]
        ca = ca[0] if ca else {}
    print(json.dumps({"ok": True, "flops": float(ca.get("flops", -1))}))
""")


@pytest.mark.slow
def test_sharded_memcom_train_compiles_8dev(tmp_path):
    """End-to-end: the MemCom Phase-1 train step lowers + compiles SPMD
    on an 8-device (4 data × 2 model) placeholder mesh."""
    script = tmp_path / "mini_dryrun.py"
    script.write_text(MINI_DRYRUN)
    res = subprocess.run(
        [sys.executable, str(script)], capture_output=True, text=True,
        timeout=900, env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd="/root/repo")
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["flops"] != 0
