"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see the real single CPU device; only launch/dryrun.py forces the
512-device placeholder topology (and does so before importing jax)."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
