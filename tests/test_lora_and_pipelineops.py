"""LoRA adapter algebra + misc distributed-substrate units."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.lora import init_lora, merge_lora
from repro.models import transformer as tfm
from repro.utils.pytree import tree_flatten_with_names


def test_lora_targets_only_attention_kernels():
    cfg = get_smoke_config("smollm-135m")
    params = tfm.init_params(cfg, 0)
    lora = init_lora(params, ("wq", "wk"), rank=4, seed=1)
    names = [n for n, _ in tree_flatten_with_names(lora)]
    assert names, "no adapters created"
    assert all("attn" in n for n in names)
    assert all(n.endswith(("/a", "/b")) for n in names)
    assert not any("/wv/" in n or "/wo/" in n for n in names)


def test_lora_zero_init_is_identity():
    """b = 0 at init ⇒ merged weights == base weights exactly."""
    cfg = get_smoke_config("smollm-135m")
    params = tfm.init_params(cfg, 0)
    lora = init_lora(params, ("wq", "wk", "wv", "wo"), rank=4, seed=1)
    merged = merge_lora(params, lora)
    for (n, a), (_, b) in zip(tree_flatten_with_names(params),
                              tree_flatten_with_names(merged)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=n)


def test_lora_merge_linearity(rng):
    """merge(w, a, b) == w + (alpha/r)·a@b on every adapted leaf."""
    cfg = get_smoke_config("smollm-135m")
    params = tfm.init_params(cfg, 0)
    lora = init_lora(params, ("wq",), rank=4, seed=1)
    # randomize b so the delta is nonzero
    lora = jax.tree.map(
        lambda x: jnp.asarray(rng.standard_normal(x.shape) * 0.1, x.dtype),
        lora)
    merged = merge_lora(params, lora, alpha=16.0, rank=4)
    flat_p = dict(tree_flatten_with_names(params))
    flat_m = dict(tree_flatten_with_names(merged))
    flat_l = dict(tree_flatten_with_names(lora))
    adapted = {n.rsplit("/", 1)[0] for n in flat_l}
    for base in adapted:
        w = flat_p[base]
        expect = w + (16.0 / 4) * (flat_l[base + "/a"] @ flat_l[base + "/b"])
        np.testing.assert_allclose(np.asarray(flat_m[base]),
                                   np.asarray(expect), atol=1e-5, rtol=1e-5)
    # non-adapted leaves untouched
    for n, w in flat_p.items():
        if n not in {b for b in adapted}:
            np.testing.assert_array_equal(np.asarray(w),
                                          np.asarray(flat_m[n]))


def test_input_specs_cover_every_objective():
    """input_specs yields ShapeDtypeStructs (never arrays) for all cells."""
    from repro.launch.steps import input_specs, default_objective, \
        shape_by_name

    class _M:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    # use a tiny real mesh for NamedSharding construction
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ("smollm-135m", "whisper-medium", "mamba2-370m"):
        for shape_name in ("train_4k", "prefill_32k", "decode_32k"):
            spec = input_specs(arch, shape_name, mesh)
            for leaf in jax.tree.leaves(spec):
                assert isinstance(leaf, jax.ShapeDtypeStruct)
            obj = default_objective(arch, shape_by_name(shape_name))
            if arch == "mamba2-370m":
                assert obj in ("lm_train", "prefill", "decode")
            if arch == "whisper-medium" and shape_name != "decode_32k":
                assert "frames" in spec
