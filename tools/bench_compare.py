"""Deterministic perf-regression gate over the traffic bench.

Diffs a current ``artifacts/bench/*.json`` (``serving_bench.json`` or
``traffic_bench.json``) against a committed baseline and exits non-zero
on a virtual-clock metric regression.  This is only sound because the
traffic section runs on a :class:`~repro.serving.clock.VirtualClock`:
for one (scenario, seed) the scoreboard is a pure function of the code,
so any drift beyond tolerance is a real behavior change, not noise.

The gate reads the **fixed-budget** sub-run (the autotuned run resizes
its own budgets, so its numbers track the controller, not the engine)
and refuses to compare across different scenarios: if the baseline's
seed/load/sizing keys differ from the current run's, that is a baseline
refresh, not a regression, and the tool exits 2 telling you so.

Gated metrics and their directions::

    decode_gap_p99_s   lower is better
    ttft_p99_s         lower is better
    goodput_rps        higher is better
    tokens_per_step    higher is better
    tokens_per_s_per_device  higher is better
    completed          higher is better

Usage::

    python -m tools.bench_compare artifacts/bench/serving_bench.json \\
        --baseline artifacts/bench/baseline/traffic_bench.json

Exit codes: 0 within tolerance, 1 regression, 2 usage/scenario errors.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple

#: metric -> direction ("lower" | "higher"); read from section["fixed"]
GATED_METRICS = (
    ("decode_gap_p99_s", "lower"),
    ("ttft_p99_s", "lower"),
    ("goodput_rps", "higher"),
    ("tokens_per_step", "higher"),
    ("tokens_per_s_per_device", "higher"),
    ("completed", "higher"),
)

#: scenario identity: comparing across different values of these keys is
#: meaningless, so the gate refuses rather than report green/red noise
SCENARIO_KEYS = (
    "seed", "process", "num_tasks", "num_requests", "rate_rps",
    "zipf_alpha", "priority_classes", "slots", "prefix_capacity",
    "host_capacity", "compile_token_budget", "promote_layer_budget",
    "slo_ttft_s",
)

DEFAULT_REL_TOL = 0.05


def find_traffic_section(doc: dict) -> Optional[dict]:
    """Locate the traffic section: top-level ``traffic`` key
    (traffic_bench.json / serving_bench.json) or the doc itself if it
    already carries the scenario keys."""
    sec = doc.get("traffic")
    if isinstance(sec, dict):
        return sec
    if "fixed" in doc and "seed" in doc:
        return doc
    return None


def scenario_mismatches(cur: dict, base: dict) -> List[str]:
    out = []
    for k in SCENARIO_KEYS:
        if cur.get(k) != base.get(k):
            out.append(f"{k}: current={cur.get(k)!r} "
                       f"baseline={base.get(k)!r}")
    return out


def compare(cur: dict, base: dict,
            rel_tol: float = DEFAULT_REL_TOL
            ) -> Tuple[List[str], List[Tuple]]:
    """Compare the fixed sub-runs; returns (report_lines, regressions).

    A metric regresses when it moves in its bad direction by more than
    ``rel_tol`` relative to the baseline value (absolute slack 1e-9 so
    a zero baseline cannot make every nonzero reading a regression of
    infinite ratio).
    """
    cf, bf = cur.get("fixed", {}), base.get("fixed", {})
    lines: List[str] = []
    regressions: List[Tuple] = []
    for metric, direction in GATED_METRICS:
        b, c = bf.get(metric), cf.get(metric)
        if b is None or c is None:
            regressions.append((metric, b, c, "missing"))
            lines.append(f"  {metric:<26} MISSING "
                         f"(baseline={b!r} current={c!r})")
            continue
        b, c = float(b), float(c)
        slack = rel_tol * abs(b) + 1e-9
        bad = (c > b + slack) if direction == "lower" else (c < b - slack)
        delta = c - b
        pct = (delta / b * 100.0) if b else float("inf") if delta else 0.0
        verdict = "REGRESSION" if bad else "ok"
        lines.append(f"  {metric:<26} base={b:.6g} cur={c:.6g} "
                     f"delta={pct:+.2f}% ({direction} is better) "
                     f"-> {verdict}")
        if bad:
            regressions.append((metric, b, c, f"{pct:+.2f}%"))
    # informational: per-phase self-time drift from the profiler report
    cp = (cur.get("profile") or {}).get("phases", {})
    bp = (base.get("profile") or {}).get("phases", {})
    for phase in sorted(set(cp) & set(bp)):
        b, c = bp[phase].get("self_s"), cp[phase].get("self_s")
        if isinstance(b, (int, float)) and isinstance(c, (int, float)):
            pct = ((c - b) / b * 100.0) if b else 0.0
            lines.append(f"  [info] {phase}_self_s".ljust(28)
                         + f" base={b:.6g} cur={c:.6g} delta={pct:+.2f}%")
    return lines, regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail on virtual-clock perf regressions vs a "
                    "committed bench baseline")
    ap.add_argument("current", help="bench JSON from the run under test")
    ap.add_argument("--baseline", required=True,
                    help="committed baseline bench JSON")
    ap.add_argument("--rel-tol", type=float, default=DEFAULT_REL_TOL,
                    help="allowed relative drift in the bad direction "
                         f"(default {DEFAULT_REL_TOL})")
    args = ap.parse_args(argv)

    docs: Dict[str, dict] = {}
    for label, path in (("current", args.current),
                        ("baseline", args.baseline)):
        try:
            with open(path) as fh:
                docs[label] = json.load(fh)
        except (OSError, ValueError) as e:
            print(f"bench-compare: cannot read {label} {path!r}: {e}",
                  file=sys.stderr)
            return 2
    cur = find_traffic_section(docs["current"])
    base = find_traffic_section(docs["baseline"])
    if cur is None or base is None:
        which = "current" if cur is None else "baseline"
        print(f"bench-compare: no traffic section in the {which} file",
              file=sys.stderr)
        return 2
    mism = scenario_mismatches(cur, base)
    if mism:
        print("bench-compare: baseline scenario mismatch — refresh the "
              "baseline instead of comparing apples to oranges:")
        for m in mism:
            print(f"  {m}")
        return 2
    lines, regressions = compare(cur, base, rel_tol=args.rel_tol)
    print(f"bench-compare: {args.current} vs {args.baseline} "
          f"(rel tol {args.rel_tol:g})")
    for ln in lines:
        print(ln)
    if regressions:
        print(f"bench-compare: {len(regressions)} regression(s) — "
              "investigate, or refresh artifacts/bench/baseline/ with "
              "justification in the PR")
        return 1
    print("bench-compare: within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
