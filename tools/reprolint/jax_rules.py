"""Rule family 1 — JAX / determinism hazards.

The serving stack's headline guarantee is that a whole simulation is a
pure function of (scenario, seed) on the injected virtual clock.  Every
rule here bans a way that guarantee has been (or could be) broken:
wall-clock reads outside ``serving/clock.py``, global/unseeded RNG,
Python control flow on traced values inside jitted functions, host syncs
in the decode loop, mutable default arguments, and ``jax.jit`` calls
that trace known-static config params.
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set

from .core import Finding, Module, Rule, call_kwarg, dotted, rule

# ---------------------------------------------------------------------------
# wall-clock
# ---------------------------------------------------------------------------

_WALL_CLOCK_CALLS = {
    "time.time", "time.perf_counter", "time.monotonic", "time.time_ns",
    "time.perf_counter_ns", "time.monotonic_ns",
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
}


@rule
class WallClockRule(Rule):
    id = "wall-clock"
    family = "jax"
    description = (
        "Direct wall-clock reads (time.time/perf_counter/monotonic, "
        "datetime.now/utcnow/today) outside serving/clock.py.  The "
        "serving stack reads time through the injected clock so a "
        "simulation replays bit-identically; passing time.perf_counter "
        "*as a callable default* is fine — calling it is not.")

    def applies_to(self, path: str) -> bool:
        return not path.endswith("serving/clock.py")

    def check(self, mod: Module) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                name = dotted(node.func)
                if name in _WALL_CLOCK_CALLS:
                    yield mod.finding(
                        self.id, node,
                        f"wall-clock read {name}() — inject a clock "
                        "(serving/clock.py) instead; timestamps must be a "
                        "function of the work performed, not the host")


# ---------------------------------------------------------------------------
# unseeded-random
# ---------------------------------------------------------------------------

# legacy numpy global-state API (np.random.<fn> mutates a hidden global
# RNG; any call order change changes every downstream draw)
_NP_LEGACY = {
    "seed", "random", "rand", "randn", "randint", "random_sample", "ranf",
    "sample", "choice", "shuffle", "permutation", "uniform", "normal",
    "standard_normal", "poisson", "exponential", "beta", "binomial",
    "bytes", "gamma", "geometric", "integers",
}
# stdlib random module-level functions (same hidden global state)
_PY_RANDOM = {
    "seed", "random", "randint", "randrange", "choice", "choices",
    "shuffle", "sample", "uniform", "gauss", "normalvariate", "betavariate",
    "expovariate", "getrandbits", "triangular", "vonmisesvariate",
}


@rule
class UnseededRandomRule(Rule):
    id = "unseeded-random"
    family = "jax"
    description = (
        "Global-state or unseeded RNG: legacy np.random.<fn>() calls, "
        "stdlib random.<fn>() module functions, np.random.default_rng() "
        "with no seed, or random.Random() with no seed.  Use "
        "np.random.default_rng(seed) / random.Random(seed) and thread "
        "the generator explicitly.")

    def check(self, mod: Module) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted(node.func)
            if name.startswith(("np.random.", "numpy.random.")):
                attr = name.rsplit(".", 1)[1]
                if attr in _NP_LEGACY:
                    yield mod.finding(
                        self.id, node,
                        f"legacy global-state RNG {name}() — use "
                        "np.random.default_rng(seed) and pass the "
                        "generator explicitly")
                elif attr == "default_rng" and not node.args \
                        and not node.keywords:
                    yield mod.finding(
                        self.id, node,
                        "np.random.default_rng() with no seed draws OS "
                        "entropy — results differ run to run")
            elif name.rsplit(".", 1)[0] == "random" \
                    and name.rsplit(".", 1)[1] in _PY_RANDOM:
                yield mod.finding(
                    self.id, node,
                    f"stdlib global-state RNG {name}() — use "
                    "random.Random(seed)")
            elif name == "random.Random" and not node.args \
                    and not node.keywords:
                yield mod.finding(
                    self.id, node,
                    "random.Random() with no seed is nondeterministic")


# ---------------------------------------------------------------------------
# traced-branch
# ---------------------------------------------------------------------------


def _jit_static_names(call: ast.Call,
                      fn: Optional[ast.FunctionDef]) -> Optional[Set[str]]:
    """Parameter names a jax.jit call marks static.  ``call`` is the
    ``jax.jit(...)`` / ``partial(jax.jit, ...)`` node; ``fn`` the wrapped
    function when resolvable.  Returns None when the static set cannot be
    determined statically (give up rather than false-positive)."""
    names: Set[str] = set()
    argnames = call_kwarg(call, "static_argnames")
    if argnames is not None:
        if isinstance(argnames, ast.Constant) and \
                isinstance(argnames.value, str):
            names.add(argnames.value)
        elif isinstance(argnames, (ast.Tuple, ast.List)):
            for elt in argnames.elts:
                if isinstance(elt, ast.Constant) and \
                        isinstance(elt.value, str):
                    names.add(elt.value)
                else:
                    return None
        else:
            return None
    argnums = call_kwarg(call, "static_argnums")
    if argnums is not None:
        if fn is None:
            return None
        positions = []
        if isinstance(argnums, ast.Constant) and \
                isinstance(argnums.value, int):
            positions = [argnums.value]
        elif isinstance(argnums, (ast.Tuple, ast.List)):
            for elt in argnums.elts:
                if isinstance(elt, ast.Constant) and \
                        isinstance(elt.value, int):
                    positions.append(elt.value)
                else:
                    return None
        else:
            return None
        params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
        for pos in positions:
            if 0 <= pos < len(params):
                names.add(params[pos])
    return names


def _is_jax_jit(expr: ast.AST) -> Optional[ast.Call]:
    """Return the jit-configuring Call for ``@jax.jit``-style decorators
    and ``jax.jit(...)`` / ``[functools.]partial(jax.jit, ...)`` calls."""
    if isinstance(expr, ast.Call):
        name = dotted(expr.func)
        if name in ("jax.jit", "jit"):
            return expr
        if name in ("functools.partial", "partial") and expr.args and \
                dotted(expr.args[0]) in ("jax.jit", "jit"):
            return expr
    return None


_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size", "sharding"}
_HOST_FNS = {"len", "isinstance", "hasattr", "getattr", "type"}


class _TracedParamUse(ast.NodeVisitor):
    """Does this expression use a (non-static) parameter as a *value*?

    Shape/dtype attribute access and len()/isinstance() calls are
    trace-time python — only genuine value uses count."""

    def __init__(self, params: Set[str]):
        self.params = params
        self.hit: Optional[ast.Name] = None

    def visit_Attribute(self, node: ast.Attribute):
        if node.attr in _SHAPE_ATTRS:
            return  # x.shape — static under tracing
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        if isinstance(node.func, ast.Name) and node.func.id in _HOST_FNS:
            return
        self.generic_visit(node)

    def visit_Compare(self, node: ast.Compare):
        # `x is None` / `x is not None` — python-level identity, fine
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name):
        if self.hit is None and node.id in self.params:
            self.hit = node


@rule
class TracedBranchRule(Rule):
    id = "traced-branch"
    family = "jax"
    description = (
        "Python if/while/assert on a traced value inside a jax.jit'ed "
        "function: the branch runs once at trace time on an abstract "
        "tracer (ConcretizationTypeError at best, a silently baked-in "
        "branch at worst).  Use lax.cond/lax.select, or mark the "
        "argument static.")

    def check(self, mod: Module) -> Iterable[Finding]:
        # pass 1: names wrapped via jax.jit(<name>, ...) calls
        wrapped: dict = {}
        for node in ast.walk(mod.tree):
            call = _is_jax_jit(node)
            if call is not None and call.args:
                target = call.args[0]
                if dotted(target) not in ("jax.jit", "jit") and \
                        isinstance(target, ast.Name):
                    wrapped[target.id] = call
        # pass 2: every function that is jitted by decorator or wrapping
        for fn in ast.walk(mod.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            jit_call = None
            for dec in fn.decorator_list:
                jit_call = _is_jax_jit(dec)
                if jit_call is None and dotted(dec) in ("jax.jit", "jit"):
                    jit_call = ast.Call(func=dec, args=[], keywords=[])
                if jit_call is not None:
                    break
            if jit_call is None:
                jit_call = wrapped.get(fn.name)
            if jit_call is None:
                continue
            static = _jit_static_names(jit_call, fn)
            if static is None:
                continue  # couldn't resolve the static set — stay quiet
            params = {a.arg for a in (fn.args.posonlyargs + fn.args.args
                                      + fn.args.kwonlyargs)} - static
            yield from self._scan_body(mod, fn, params)

    def _scan_body(self, mod: Module, fn, params: Set[str]):
        for node in ast.walk(fn):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not fn:
                continue  # nested defs get their own jit analysis (if any)
            tests: List[ast.expr] = []
            kind = None
            if isinstance(node, ast.If):
                tests, kind = [node.test], "if"
            elif isinstance(node, ast.While):
                tests, kind = [node.test], "while"
            elif isinstance(node, ast.Assert):
                tests, kind = [node.test], "assert"
            for test in tests:
                probe = _TracedParamUse(params)
                probe.visit(test)
                if probe.hit is not None:
                    yield mod.finding(
                        self.id, node,
                        f"python `{kind}` on traced parameter "
                        f"{probe.hit.id!r} inside a jax.jit function — "
                        "use lax.cond/lax.select or mark it static")


# ---------------------------------------------------------------------------
# host-sync-decode
# ---------------------------------------------------------------------------

_JIT_STEP_ATTRS = ("_decode", "_decode_greedy", "_prefill", "_fused",
                   "_draft", "_program")


@rule
class HostSyncRule(Rule):
    id = "host-sync-decode"
    family = "jax"
    description = (
        "Host synchronization in the serving hot path: .item() on a "
        "device array, or float()/int() wrapped directly around a jitted "
        "step call.  Each sync stalls the dispatch pipeline once per "
        "decode step; pull values to host once per batch via np.asarray "
        "at the single sanctioned sync point.")

    def applies_to(self, path: str) -> bool:
        return "serving/" in path

    def check(self, mod: Module) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "item" and not node.args:
                yield mod.finding(
                    self.id, node,
                    ".item() forces a device→host sync per element — "
                    "np.asarray the whole batch once instead")
            elif isinstance(node.func, ast.Name) and \
                    node.func.id in ("float", "int") and node.args:
                inner = node.args[0]
                if isinstance(inner, ast.Call):
                    name = dotted(inner.func)
                    if any(name == f"self.{a}" for a in _JIT_STEP_ATTRS):
                        yield mod.finding(
                            self.id, node,
                            f"{node.func.id}() directly on the jitted step "
                            f"{name}() blocks on the device — keep the "
                            "result async and sync once per step")


# ---------------------------------------------------------------------------
# mutable-default
# ---------------------------------------------------------------------------

_IMMUTABLE_CALLS = {"frozenset", "tuple", "object"}


@rule
class MutableDefaultRule(Rule):
    id = "mutable-default"
    family = "jax"
    description = (
        "Mutable default argument ([], {}, set(), np.array(...)): "
        "evaluated once at def time and shared across calls — state "
        "leaks between requests.  Default to None (or frozenset()/a "
        "tuple) and construct inside the body.")

    def check(self, mod: Module) -> Iterable[Finding]:
        for fn in ast.walk(mod.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            defaults = list(fn.args.defaults) + \
                [d for d in fn.args.kw_defaults if d is not None]
            for d in defaults:
                bad = None
                if isinstance(d, (ast.List, ast.Dict, ast.Set)):
                    bad = {ast.List: "[]", ast.Dict: "{}",
                           ast.Set: "a set literal"}[type(d)]
                elif isinstance(d, ast.Call):
                    name = dotted(d.func)
                    base = name.split(".")[-1]
                    if base in ("list", "dict", "set", "defaultdict",
                                "OrderedDict", "deque", "array", "zeros",
                                "ones", "empty"):
                        bad = f"{name}(...)"
                if bad is not None:
                    yield mod.finding(
                        self.id, d,
                        f"mutable default {bad} in {fn.name}() is shared "
                        "across calls — default to None and build it in "
                        "the body")


# ---------------------------------------------------------------------------
# jit-static-hint
# ---------------------------------------------------------------------------

# parameters that are always trace-static in this codebase: ModelConfig
# dataclasses, meshes, and python-mode switches.  Tracing them either
# crashes (unhashable) or silently retraces per call.
_KNOWN_STATIC_PARAMS = {"cfg", "config", "dcfg", "mesh", "interpret",
                        "causal", "kv_layout"}


@rule
class JitStaticHintRule(Rule):
    id = "jit-static-hint"
    family = "jax"
    description = (
        "jax.jit over a function taking a known-static config param "
        "(cfg/config/mesh/interpret/...) without declaring it in "
        "static_argnums/static_argnames — the call either fails on an "
        "unhashable tracer or retraces every step.")

    def check(self, mod: Module) -> Iterable[Finding]:
        fns = {f.name: f for f in ast.walk(mod.tree)
               if isinstance(f, ast.FunctionDef)}
        for node in ast.walk(mod.tree):
            call = _is_jax_jit(node)
            if call is None or not isinstance(node, ast.Call):
                continue
            # which function does this jit wrap?
            fn = None
            if call.args:
                target = call.args[0]
                if dotted(target) in ("jax.jit", "jit") and \
                        len(call.args) > 1:
                    target = call.args[1]
                if isinstance(target, ast.Name):
                    fn = fns.get(target.id)
            if fn is None:
                continue
            static = _jit_static_names(call, fn)
            if static is None:
                continue
            params = [a.arg for a in fn.args.posonlyargs + fn.args.args
                      + fn.args.kwonlyargs]
            missing = [p for p in params
                       if p in _KNOWN_STATIC_PARAMS and p not in static]
            for p in missing:
                yield mod.finding(
                    self.id, node,
                    f"jax.jit({fn.name}) traces parameter {p!r} which is "
                    "config-static — add it to static_argnames")


# decorator form of jit-static-hint shares the implementation above via a
# second scan: @jax.jit / @partial(jax.jit, ...) directly on a def.
@rule
class JitStaticHintDecoratorRule(Rule):
    id = "jit-static-hint-decorator"
    family = "jax"
    description = (
        "Decorator form of jit-static-hint: @jax.jit / "
        "@functools.partial(jax.jit, ...) on a def whose signature has a "
        "known-static config param not named in static_argnames.")

    def check(self, mod: Module) -> Iterable[Finding]:
        for fn in ast.walk(mod.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for dec in fn.decorator_list:
                call = _is_jax_jit(dec)
                if call is None and dotted(dec) in ("jax.jit", "jit"):
                    call = ast.Call(func=dec, args=[], keywords=[])
                if call is None:
                    continue
                static = _jit_static_names(call, fn)
                if static is None:
                    continue
                params = [a.arg for a in fn.args.posonlyargs + fn.args.args
                          + fn.args.kwonlyargs]
                for p in params:
                    if p in _KNOWN_STATIC_PARAMS and p not in static:
                        yield mod.finding(
                            self.id, dec,
                            f"@jax.jit on {fn.name}() traces parameter "
                            f"{p!r} which is config-static — add it to "
                            "static_argnames")
