"""CLI: ``python -m tools.reprolint [paths...]``.

Exits 0 when every finding is suppressed or baselined, 1 on new
findings, 2 on usage/baseline-format errors.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from . import RULES
from .core import Baseline, BaselineError, iter_py_files, lint_file

DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="reprolint",
        description="repo-native static analysis (jax determinism hazards, "
                    "serving refcount/state-machine checks, pallas kernel "
                    "contracts)")
    ap.add_argument("paths", nargs="*", default=["src", "tests", "benchmarks"],
                    help="files or directories to lint "
                         "(default: src tests benchmarks)")
    ap.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE,
                    help="baseline JSON of grandfathered findings")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write all current findings to the baseline file "
                         "(each entry still needs a hand-written "
                         "justification before CI accepts it)")
    ap.add_argument("--rule", action="append", dest="rules", default=None,
                    metavar="RULE-ID", help="run only these rule ids")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--include-fixtures", action="store_true",
                    help="also lint tests/lint_fixtures (deliberately "
                         "violating files; excluded by default)")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, r in sorted(RULES.items()):
            print(f"{rid}  [{r.family}]")
            print(f"    {r.description}")
        return 0

    if args.rules:
        unknown = [r for r in args.rules if r not in RULES]
        if unknown:
            print(f"reprolint: unknown rule(s): {', '.join(unknown)}; "
                  "see --list-rules", file=sys.stderr)
            return 2

    files = list(iter_py_files(args.paths,
                               include_fixtures=args.include_fixtures))
    if not files:
        print(f"reprolint: no python files under {args.paths}",
              file=sys.stderr)
        return 2

    findings = []
    for f in files:
        findings.extend(lint_file(f, rule_ids=args.rules))

    if args.update_baseline:
        Baseline.dump(findings, args.baseline)
        print(f"reprolint: wrote {len(findings)} finding(s) to "
              f"{args.baseline} — fill in every justification before "
              "committing")
        return 0

    matched = 0
    if not args.no_baseline:
        try:
            baseline = Baseline.load(args.baseline)
        except BaselineError as e:
            print(f"reprolint: bad baseline: {e}", file=sys.stderr)
            return 2
        except json.JSONDecodeError as e:
            print(f"reprolint: baseline is not valid JSON: {e}",
                  file=sys.stderr)
            return 2
        findings, matched = baseline.filter(findings)

    if args.format == "json":
        print(json.dumps([f.__dict__ for f in findings], indent=2))
    else:
        for f in findings:
            print(f.render())

    tail = f", {matched} baselined" if matched else ""
    if findings:
        print(f"\nreprolint: {len(findings)} new finding(s) across "
              f"{len(files)} file(s){tail}", file=sys.stderr)
        return 1
    print(f"reprolint: clean — {len(files)} file(s){tail}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
