"""Rule family 3 — pallas kernel contracts.

Every kernel in ``src/repro/kernels/`` obeys three contracts:

* compiler params come from the ``pltpu_compat`` shim, never from
  ``pltpu.CompilerParams`` directly (the class was renamed across jax
  releases; the shim is the one place that knows);
* a ``BlockSpec`` index map takes exactly ``grid rank +
  num_scalar_prefetch`` positional arguments — an arity mismatch
  compiles on some jax versions and silently mis-tiles on others;
* each public kernel entry point has a registered jnp reference twin
  (``registry.REFERENCE_TWINS`` → a function in ``jnp_impl.py`` or
  ``ref.py``) so parity tests always have an oracle.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

from .core import Finding, Module, Rule, call_kwarg, dotted, rule

_NON_KERNEL_MODULES = {"__init__", "ops", "jnp_impl", "ref", "pltpu_compat",
                       "registry"}


def _in_kernels_dir(path: str) -> bool:
    return Path(path).parent.name == "kernels"


# ---------------------------------------------------------------------------
# pltpu-compat
# ---------------------------------------------------------------------------


@rule
class PltpuCompatRule(Rule):
    id = "pltpu-compat"
    family = "kernels"
    description = (
        "Kernels must import CompilerParams from "
        "repro.kernels.pltpu_compat, never pltpu.CompilerParams / "
        "pltpu.TPUCompilerParams directly — the class name changed "
        "across jax releases and the shim is the single compatibility "
        "point.")

    def applies_to(self, path: str) -> bool:
        return _in_kernels_dir(path) and \
            Path(path).stem != "pltpu_compat"

    def check(self, mod: Module) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Attribute) and \
                    node.attr in ("CompilerParams", "TPUCompilerParams"):
                recv = dotted(node.value)
                if recv:  # pltpu.CompilerParams, tpu.TPUCompilerParams, ...
                    yield mod.finding(
                        self.id, node,
                        f"direct {recv}.{node.attr} — import CompilerParams "
                        "from repro.kernels.pltpu_compat (version shim)")
            elif isinstance(node, ast.ImportFrom) and node.module and \
                    "pallas" in node.module:
                for alias in node.names:
                    if alias.name in ("CompilerParams", "TPUCompilerParams"):
                        yield mod.finding(
                            self.id, node,
                            f"from {node.module} import {alias.name} — "
                            "import it from repro.kernels.pltpu_compat "
                            "(version shim)")


# ---------------------------------------------------------------------------
# blockspec-arity
# ---------------------------------------------------------------------------


def _lambda_arity(lam: ast.Lambda) -> int:
    """Positional parameters without defaults (defaults are trace-time
    captures like ``rep=rep``, not grid indices)."""
    args = lam.args
    return len(args.posonlyargs) + len(args.args) - len(args.defaults)


def _grid_rank(grid: ast.expr, fn: Optional[ast.AST]) -> Optional[int]:
    """Rank of a grid expression: a literal tuple's length, resolving one
    level of ``name = (...)`` indirection inside the enclosing function."""
    if isinstance(grid, (ast.Tuple, ast.List)):
        return len(grid.elts)
    if isinstance(grid, ast.Name) and fn is not None:
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                    isinstance(node.targets[0], ast.Name) and \
                    node.targets[0].id == grid.id and \
                    isinstance(node.value, (ast.Tuple, ast.List)):
                return len(node.value.elts)
    return None


@rule
class BlockSpecArityRule(Rule):
    id = "blockspec-arity"
    family = "kernels"
    description = (
        "A BlockSpec index map must take grid-rank + num_scalar_prefetch "
        "positional args (extra defaulted params are fine).  A mismatch "
        "is a silent mis-tile on jax versions that don't validate it.")

    def applies_to(self, path: str) -> bool:
        return _in_kernels_dir(path)

    def check(self, mod: Module) -> Iterable[Finding]:
        # map each grid-bearing call to its enclosing function for name
        # resolution
        enclosing: Dict[ast.AST, ast.AST] = {}
        for fn in ast.walk(mod.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for sub in ast.walk(fn):
                    enclosing.setdefault(sub, fn)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            callee = dotted(node.func)
            tail = callee.split(".")[-1]
            if tail not in ("pallas_call", "PrefetchScalarGridSpec",
                            "GridSpec"):
                continue
            grid = call_kwarg(node, "grid")
            if grid is None:
                continue
            rank = _grid_rank(grid, enclosing.get(node))
            if rank is None:
                continue  # not statically resolvable — stay quiet
            prefetch = 0
            pf = call_kwarg(node, "num_scalar_prefetch")
            if pf is not None:
                if isinstance(pf, ast.Constant) and isinstance(pf.value, int):
                    prefetch = pf.value
                else:
                    continue
            want = rank + prefetch
            for spec_kw in ("in_specs", "out_specs"):
                specs = call_kwarg(node, spec_kw)
                if specs is None:
                    continue
                spec_calls = [specs] if isinstance(specs, ast.Call) else (
                    list(specs.elts)
                    if isinstance(specs, (ast.List, ast.Tuple)) else [])
                for spec in spec_calls:
                    if not (isinstance(spec, ast.Call)
                            and dotted(spec.func).endswith("BlockSpec")):
                        continue
                    lam = None
                    if len(spec.args) >= 2 and \
                            isinstance(spec.args[1], ast.Lambda):
                        lam = spec.args[1]
                    else:
                        im = call_kwarg(spec, "index_map")
                        if isinstance(im, ast.Lambda):
                            lam = im
                    if lam is None:
                        continue
                    got = _lambda_arity(lam)
                    if got != want:
                        yield mod.finding(
                            self.id, lam,
                            f"BlockSpec index map takes {got} positional "
                            f"args but the grid supplies {want} "
                            f"(rank {rank} + {prefetch} scalar-prefetch "
                            "refs)")


# ---------------------------------------------------------------------------
# ref-twin
# ---------------------------------------------------------------------------


def _module_functions(path: Path) -> Optional[set]:
    try:
        tree = ast.parse(path.read_text(encoding="utf-8"))
    except (OSError, SyntaxError):
        return None
    return {n.name for n in tree.body if isinstance(n, ast.FunctionDef)}


def _load_registry(kernels_dir: Path) -> Tuple[Optional[dict], Optional[str]]:
    reg = kernels_dir / "registry.py"
    if not reg.exists():
        return None, f"no reference-twin registry at {reg.as_posix()}"
    try:
        tree = ast.parse(reg.read_text(encoding="utf-8"))
    except SyntaxError as e:
        return None, f"registry.py unparseable: {e}"
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name) and \
                node.targets[0].id == "REFERENCE_TWINS":
            try:
                return ast.literal_eval(node.value), None
            except (ValueError, SyntaxError):
                return None, ("REFERENCE_TWINS must be a pure literal dict "
                              "the linter can evaluate")
    return None, "registry.py defines no REFERENCE_TWINS dict"


@rule
class RefTwinRule(Rule):
    id = "ref-twin"
    family = "kernels"
    description = (
        "Every public pallas kernel entry point needs a registered jnp "
        "reference twin (REFERENCE_TWINS in kernels/registry.py pointing "
        "at a function in jnp_impl.py or ref.py) so parity tests always "
        "have an oracle — a kernel without an oracle is untestable.")

    def applies_to(self, path: str) -> bool:
        return _in_kernels_dir(path) and \
            Path(path).stem not in _NON_KERNEL_MODULES

    def check(self, mod: Module) -> Iterable[Finding]:
        # only modules that actually build a pallas kernel
        if not any(isinstance(n, ast.Call)
                   and dotted(n.func).split(".")[-1] == "pallas_call"
                   for n in ast.walk(mod.tree)):
            return
        kernels_dir = Path(mod.path).parent
        modname = Path(mod.path).stem
        registry, err = _load_registry(kernels_dir)
        public = [n for n in mod.tree.body
                  if isinstance(n, ast.FunctionDef)
                  and not n.name.startswith("_")]
        if registry is None:
            if public:
                yield mod.finding(self.id, public[0], err)
            return
        twin_fns: Dict[str, Optional[set]] = {}
        for fn in public:
            key = f"{modname}:{fn.name}"
            twin = registry.get(key)
            if twin is None:
                yield mod.finding(
                    self.id, fn,
                    f"public kernel {key} has no REFERENCE_TWINS entry in "
                    "kernels/registry.py — register its jnp oracle")
                continue
            try:
                twin_mod, twin_fn = twin.split(":")
            except (AttributeError, ValueError):
                yield mod.finding(
                    self.id, fn,
                    f"REFERENCE_TWINS[{key!r}] = {twin!r} — expected "
                    "'jnp_impl:<fn>' or 'ref:<fn>'")
                continue
            if twin_mod not in ("jnp_impl", "ref"):
                yield mod.finding(
                    self.id, fn,
                    f"REFERENCE_TWINS[{key!r}] points at {twin_mod!r} — "
                    "twins must live in jnp_impl.py or ref.py")
                continue
            if twin_mod not in twin_fns:
                twin_fns[twin_mod] = _module_functions(
                    kernels_dir / f"{twin_mod}.py")
            fns = twin_fns[twin_mod]
            if fns is not None and twin_fn not in fns:
                yield mod.finding(
                    self.id, fn,
                    f"REFERENCE_TWINS[{key!r}] -> {twin!r} but "
                    f"{twin_mod}.py defines no function {twin_fn!r}")
