"""reprolint core: rule registry, suppressions, baseline, file runner.

The linter is deliberately dependency-free (stdlib ``ast`` + ``tokenize``
only) so the CI static-analysis job can run it before installing jax, and
a pre-commit hook stays fast.  Rules are small classes registered with
:func:`rule`; each receives a parsed :class:`Module` and yields
:class:`Finding`\\ s.

Three escape hatches, in order of preference:

1. **Fix the code.**  The rules encode repo invariants, not style.
2. **Per-line suppression** — ``# reprolint: ignore[rule-id] -- reason``
   on the flagged line or the line directly above it.  The reason is the
   written justification; suppressions without one are themselves
   findings (``bare-suppression``).
3. **File-level suppression** — ``# reprolint: ignore-file[rule-id] --
   reason`` anywhere in the file, for files whose *purpose* conflicts
   with a rule (benchmarks measure wall time; wall time is banned in the
   deterministic serving core).
4. **Baseline** — ``tools/reprolint/baseline.json`` grandfathers known
   findings (matched by rule + path + stripped source line, multiset
   semantics so a *new* copy of an old finding still fails).  Every
   baseline entry must carry a non-empty ``justification``.
"""

from __future__ import annotations

import ast
import io
import json
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Findings
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str          # posix path as given on the command line
    line: int          # 1-based
    message: str
    context: str = ""  # stripped source line (baseline matching key)

    def key(self) -> Tuple[str, str, str]:
        """Baseline matching key: stable across pure line-number shifts."""
        return (self.rule, self.path, self.context)

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


# ---------------------------------------------------------------------------
# Parsed module handed to rules
# ---------------------------------------------------------------------------

_SUPPRESS_RE = re.compile(
    r"#\s*reprolint:\s*(ignore-file|ignore)"
    r"(?:\[(?P<rules>[a-z0-9_,\- ]*)\])?"
    r"(?:\s*--\s*(?P<reason>.*))?\s*$")


@dataclass
class Suppression:
    line: int
    rules: Optional[frozenset]  # None == all rules
    reason: str
    file_level: bool

    def covers(self, rule_id: str) -> bool:
        return self.rules is None or rule_id in self.rules


@dataclass
class Module:
    path: str                  # as reported in findings
    source: str
    tree: ast.Module
    lines: List[str] = field(default_factory=list)
    suppressions: List[Suppression] = field(default_factory=list)

    @classmethod
    def parse(cls, path: str, source: Optional[str] = None) -> "Module":
        if source is None:
            source = Path(path).read_text(encoding="utf-8")
        tree = ast.parse(source, filename=path)
        mod = cls(path=str(Path(path).as_posix()), source=source, tree=tree,
                  lines=source.splitlines())
        mod.suppressions = list(_scan_suppressions(source))
        return mod

    def context(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def finding(self, rule: str, node_or_line, message: str) -> Finding:
        line = getattr(node_or_line, "lineno", node_or_line)
        return Finding(rule=rule, path=self.path, line=line,
                       message=message, context=self.context(line))

    # ---- suppression queries ----

    def suppressed(self, f: Finding) -> bool:
        for s in self.suppressions:
            if not s.covers(f.rule):
                continue
            if s.file_level or s.line in (f.line, f.line - 1):
                return True
        return False


def _scan_suppressions(source: str) -> Iterable[Suppression]:
    """Tokenize-based comment scan (robust to ``#`` inside strings)."""
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _SUPPRESS_RE.search(tok.string)
            if not m:
                continue
            rules = m.group("rules")
            ids = (frozenset(r.strip() for r in rules.split(",") if r.strip())
                   if rules is not None else None)
            yield Suppression(
                line=tok.start[0], rules=ids,
                reason=(m.group("reason") or "").strip(),
                file_level=m.group(1) == "ignore-file")
    except tokenize.TokenError:  # unterminated string etc; ast will complain
        return


# ---------------------------------------------------------------------------
# Rule registry
# ---------------------------------------------------------------------------

RULES: Dict[str, "Rule"] = {}


class Rule:
    """One check.  Subclasses set ``id``/``family``/``description`` and
    implement :meth:`check`, yielding findings for one module."""

    id: str = ""
    family: str = ""
    description: str = ""

    def applies_to(self, path: str) -> bool:
        return True

    def check(self, mod: Module) -> Iterable[Finding]:  # pragma: no cover
        raise NotImplementedError


def rule(cls):
    """Class decorator registering a :class:`Rule` subclass."""
    inst = cls()
    assert inst.id and inst.id not in RULES, f"bad/duplicate rule id {cls}"
    RULES[inst.id] = inst
    return cls


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------


class BaselineError(ValueError):
    pass


@dataclass
class Baseline:
    entries: List[dict] = field(default_factory=list)

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        if not path.exists():
            return cls()
        data = json.loads(path.read_text(encoding="utf-8"))
        entries = data.get("findings", [])
        for e in entries:
            for field_name in ("rule", "path", "context"):
                if field_name not in e:
                    raise BaselineError(
                        f"baseline entry missing {field_name!r}: {e}")
            if not str(e.get("justification", "")).strip():
                raise BaselineError(
                    "baseline entry without a written justification: "
                    f"{e['rule']} at {e['path']} — every grandfathered "
                    "finding must say why it is acceptable")
        return cls(entries=entries)

    def filter(self, findings: List[Finding]) -> Tuple[List[Finding], int]:
        """Remove baselined findings (multiset: each entry absorbs one
        matching finding).  Returns (new_findings, matched_count)."""
        budget: Dict[Tuple[str, str, str], int] = {}
        for e in self.entries:
            k = (e["rule"], e["path"], e["context"])
            budget[k] = budget.get(k, 0) + 1
        fresh, matched = [], 0
        for f in findings:
            k = f.key()
            if budget.get(k, 0) > 0:
                budget[k] -= 1
                matched += 1
            else:
                fresh.append(f)
        return fresh, matched

    @staticmethod
    def dump(findings: List[Finding], path: Path) -> None:
        data = {
            "comment": "reprolint baseline — grandfathered findings. Every "
                       "entry needs a justification; prefer fixing the code "
                       "or an inline '# reprolint: ignore[...] -- reason'.",
            "findings": [
                {"rule": f.rule, "path": f.path, "context": f.context,
                 "justification": "TODO: justify or fix"}
                for f in findings
            ],
        }
        path.write_text(json.dumps(data, indent=2) + "\n", encoding="utf-8")


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

SKIP_DIRS = {"__pycache__", ".git", ".venv", "node_modules", "lint_fixtures"}


def iter_py_files(paths: Iterable[str],
                  include_fixtures: bool = False) -> Iterable[Path]:
    skip = set(SKIP_DIRS)
    if include_fixtures:
        skip.discard("lint_fixtures")
    for raw in paths:
        p = Path(raw)
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if not (set(f.parts) & skip):
                    yield f


def lint_source(path: str, source: str,
                rule_ids: Optional[Iterable[str]] = None) -> List[Finding]:
    """Lint one in-memory source blob (the unit tests' entry point)."""
    mod = Module.parse(path, source)
    return _run_rules(mod, rule_ids)


def lint_file(path: Path,
              rule_ids: Optional[Iterable[str]] = None) -> List[Finding]:
    try:
        mod = Module.parse(str(path))
    except SyntaxError as e:
        return [Finding(rule="parse-error", path=str(Path(path).as_posix()),
                        line=e.lineno or 1, message=str(e))]
    return _run_rules(mod, rule_ids)


def _run_rules(mod: Module,
               rule_ids: Optional[Iterable[str]] = None) -> List[Finding]:
    active = ([RULES[r] for r in rule_ids] if rule_ids is not None
              else list(RULES.values()))
    out: List[Finding] = []
    for r in active:
        if not r.applies_to(mod.path):
            continue
        for f in r.check(mod):
            if not mod.suppressed(f):
                out.append(f)
    out.extend(_check_suppression_hygiene(mod))
    out.sort(key=lambda f: (f.path, f.line, f.rule))
    return out


def _check_suppression_hygiene(mod: Module) -> List[Finding]:
    """A suppression is a promise with a reason attached; one without a
    reason (or naming no rule) silently rots."""
    out = []
    for s in mod.suppressions:
        if not s.reason:
            out.append(mod.finding(
                "bare-suppression", s.line,
                "suppression without a justification — write "
                "'# reprolint: ignore[rule-id] -- why this is OK'"))
        elif s.rules is None:
            out.append(mod.finding(
                "bare-suppression", s.line,
                "blanket suppression — name the rule(s): "
                "'# reprolint: ignore[rule-id] -- reason'"))
    return out


# ---------------------------------------------------------------------------
# Shared AST helpers used by several rule modules
# ---------------------------------------------------------------------------


def dotted(node: ast.AST) -> str:
    """Best-effort dotted name of an expression: ``a.b.c`` -> "a.b.c"."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def call_kwarg(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def is_name_in(expr: ast.AST, names: set) -> bool:
    return any(isinstance(n, ast.Name) and n.id in names
               for n in ast.walk(expr))
