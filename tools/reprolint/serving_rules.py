"""Rule family 2 — serving protocol checks.

The paged KV cache is correct only while three conventions hold (see
``serving/block_pool.py`` invariants and docs/ARCHITECTURE.md):

* every ``incref``/``alloc`` acquisition is matched by a ``decref`` or
  ownership transfer (stored in a table/store/container) on **all** exit
  paths, including the exception edges ``PrefixSeatedError`` and
  ``OutOfBlocksError`` introduce;
* a store's ``demote_hook`` only fires after the seated guard (the KV it
  gathers out of the pool is trustworthy only while still referenced);
* the scheduler only moves requests along the legal stage machine
  declared in ``Scheduler``'s machine-readable ``LEGAL_TRANSITIONS``
  table (the same table the ``REPRO_SANITIZE=1`` runtime sanitizer
  enforces — the static and dynamic checker cross-validate each other).

The refcount checker is an intra-procedural may-leak analysis: a linear
symbolic walk over each function's statements (branch bodies walked
independently, loop bodies once) tracking acquired block sets until they
are released (``decref``) or escape (stored into an attribute/subscript/
container, or returned).  A ``raise``, or a call into a known-raising
API (``alloc``/``evict``/``put``/``put_row``/``_evict_lru``), while an
acquisition is still held flags a leak on that exception edge — unless
an enclosing ``try`` releases the acquisition in a handler or
``finally``.  The analysis prefers false negatives over false positives;
it is cross-validated by the runtime sanitizer.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .core import Finding, Module, Rule, dotted, rule

def _is_serving_target(path: str) -> bool:
    return Path(path).parent.name == "serving"


# ---------------------------------------------------------------------------
# refcount-balance
# ---------------------------------------------------------------------------

_ACQUIRE_ATTRS = {"alloc", "incref"}
_RELEASE_ATTRS = {"decref"}
_ESCAPE_METHODS = {"append", "extend", "insert", "add", "update"}
# calls that can raise PrefixSeatedError / OutOfBlocksError mid-function:
# an acquisition still held across one of these leaks on the exception edge
_KNOWN_RAISERS = {"alloc", "evict", "put", "put_row", "_evict_lru",
                  "_cow_block", "_prepare_prefill", "_seat_blocks"}


@dataclass
class _Acq:
    name: str          # tracked variable (or source collection for incref)
    line: int
    kind: str          # "alloc" | "incref"


class _FnState:
    def __init__(self):
        self.held: Dict[str, _Acq] = {}

    def copy(self) -> "_FnState":
        s = _FnState()
        s.held = dict(self.held)
        return s


@rule
class RefcountBalanceRule(Rule):
    id = "refcount-balance"
    family = "serving"
    description = (
        "Block acquisitions (BlockAllocator.alloc/incref) must be "
        "released (decref) or transferred (stored into a block table, "
        "store entry, or slot list) on every exit path — including the "
        "exception edges PrefixSeatedError/OutOfBlocksError introduce.  "
        "A held acquisition at a return, raise, or known-raising call "
        "leaks pool blocks.")

    def applies_to(self, path: str) -> bool:
        return _is_serving_target(path)

    def check(self, mod: Module) -> Iterable[Finding]:
        # parent map for try-enclosure queries
        parents: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(mod.tree):
            for child in ast.iter_child_nodes(node):
                parents[child] = node
        for fn in ast.walk(mod.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if self._has_acquisition(fn):
                    yield from self._analyze(mod, fn, parents)

    # ---- helpers ----

    @staticmethod
    def _call_attr(node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            return node.func.attr
        return None

    def _has_acquisition(self, fn) -> bool:
        for node in ast.walk(fn):
            if self._call_attr(node) in _ACQUIRE_ATTRS:
                # `.alloc(` on an allocator-ish receiver only — skip e.g.
                # unrelated .alloc attrs by requiring the receiver name
                # to mention alloc, or the call to be .incref
                if self._is_acquire(node):
                    return True
        return False

    def _is_acquire(self, node: ast.Call) -> bool:
        attr = self._call_attr(node)
        if attr == "incref":
            return True
        if attr == "alloc":
            recv = dotted(node.func.value)
            return "alloc" in recv.split(".")[-1]
        return False

    # ---- the walk ----

    def _analyze(self, mod: Module, fn, parents) -> Iterable[Finding]:
        self._findings: List[Finding] = []
        self._mod = mod
        self._parents = parents
        # map incref loop-vars to their source collection
        self._loop_src: Dict[str, str] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.For) and isinstance(node.target, ast.Name) \
                    and isinstance(node.iter, ast.Name):
                self._loop_src[node.target.id] = node.iter.id
        state = _FnState()
        self._walk(fn.body, state)
        self._flag_held(state, fn.body[-1].lineno if fn.body else fn.lineno,
                        "function exit")
        return self._findings

    def _flag_held(self, state: _FnState, line: int, where: str) -> None:
        for acq in state.held.values():
            self._findings.append(self._mod.finding(
                "refcount-balance", line,
                f"block refs acquired at line {acq.line} "
                f"({acq.kind} -> {acq.name!r}) are still held at {where} "
                "— decref them or store them in an owning structure"))
        state.held.clear()

    def _walk(self, stmts: List[ast.stmt], state: _FnState) -> bool:
        """Walk a statement list; returns False when the block always
        terminates (return/raise) before falling through."""
        for stmt in stmts:
            if isinstance(stmt, ast.Return):
                if stmt.value is not None:
                    self._mark_escapes_in(stmt.value, state)
                self._flag_held(state, stmt.lineno, "return")
                return False
            if isinstance(stmt, ast.Raise):
                if state.held and not self._released_by_enclosing_try(
                        stmt, state):
                    self._flag_held(state, stmt.lineno, "raise")
                return False
            if isinstance(stmt, ast.If):
                s_body, s_else = state.copy(), state.copy()
                ft_body = self._walk(stmt.body, s_body)
                ft_else = self._walk(stmt.orelse, s_else)
                merged: Dict[str, _Acq] = {}
                if ft_body:
                    merged.update(s_body.held)
                if ft_else:
                    merged.update(s_else.held)
                state.held = merged
                if not ft_body and not ft_else:
                    return False
                continue
            if isinstance(stmt, (ast.For, ast.While)):
                if isinstance(stmt, ast.For):
                    self._scan_dangers(stmt.iter, state)
                else:
                    self._scan_dangers(stmt.test, state)
                body_state = state.copy()
                self._walk(stmt.body, body_state)
                self._walk(stmt.orelse, body_state)
                state.held = dict(body_state.held)
                continue
            if isinstance(stmt, ast.Try):
                # conservative: treat handlers/finally as alternate exits;
                # dangers inside the body consult the handlers for releases
                body_state = state.copy()
                ft = self._walk(stmt.body, body_state)
                for h in stmt.handlers:
                    self._walk(h.body, state.copy())
                if stmt.finalbody:
                    self._walk(stmt.finalbody, body_state)
                state.held = dict(body_state.held)
                if not ft and not stmt.finalbody:
                    return False
                continue
            if isinstance(stmt, ast.With):
                self._walk(stmt.body, state)
                continue
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue  # nested defs analyzed on their own
            self._linear(stmt, state)
        return True

    # ---- one non-branching statement ----

    def _linear(self, stmt: ast.stmt, state: _FnState) -> None:
        self._scan_dangers(stmt, state)
        # releases first (decref(x) while x held)
        for node in ast.walk(stmt):
            attr = self._call_attr(node)
            if attr in _RELEASE_ATTRS:
                for arg in node.args:
                    self._release_names_in(arg, state)
            elif attr in _ESCAPE_METHODS:
                for arg in node.args:
                    self._mark_escapes_in(arg, state)
        # acquisitions + escapes via assignment
        if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = (stmt.targets if isinstance(stmt, ast.Assign)
                       else [stmt.target])
            value = stmt.value
            escape_target = any(
                isinstance(t, (ast.Attribute, ast.Subscript))
                or isinstance(t, (ast.Tuple, ast.List)) and any(
                    isinstance(e, (ast.Attribute, ast.Subscript))
                    for e in t.elts)
                for t in targets)
            acq = self._find_acquire(value) if value is not None else None
            if acq is not None:
                if escape_target:
                    pass  # acquired straight into an owning structure
                else:
                    tname = self._simple_target(targets)
                    if tname is not None:
                        state.held[tname] = _Acq(tname, stmt.lineno,
                                                 self._call_attr(acq))
                    # tuple-unpack etc: give up tracking (may-miss)
            elif value is not None and escape_target:
                self._mark_escapes_in(value, state)
        elif isinstance(stmt, ast.Expr):
            acq = self._find_acquire(stmt.value)
            if acq is not None:
                attr = self._call_attr(acq)
                if attr == "incref":
                    name = self._incref_tracked_name(acq)
                    if name is not None:
                        state.held[name] = _Acq(name, stmt.lineno, "incref")
                else:
                    self._findings.append(self._mod.finding(
                        "refcount-balance", stmt.lineno,
                        "alloc() result discarded — the blocks can never "
                        "be released"))

    def _scan_dangers(self, node: ast.AST, state: _FnState) -> None:
        if not state.held:
            return
        for sub in ast.walk(node):
            attr = self._call_attr(sub)
            if attr in _KNOWN_RAISERS and not self._is_acquire(sub):
                if not self._released_by_enclosing_try(sub, state):
                    for acq in list(state.held.values()):
                        self._findings.append(self._mod.finding(
                            "refcount-balance", sub.lineno,
                            f"call to .{attr}() may raise "
                            "(PrefixSeatedError/OutOfBlocksError) while "
                            f"block refs from line {acq.line} are still "
                            f"held ({acq.name!r}) — release them first or "
                            "wrap in try/finally"))
                    state.held.clear()  # one report per hazard

    def _released_by_enclosing_try(self, node: ast.AST,
                                   state: _FnState) -> bool:
        """True when an enclosing ``try`` textually decrefs a held name in
        a handler or ``finally`` — the exception edge is then covered."""
        held = set(state.held)
        cur = self._parents.get(node)
        while cur is not None and not isinstance(
                cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if isinstance(cur, ast.Try):
                cleanup: List[ast.stmt] = list(cur.finalbody)
                for h in cur.handlers:
                    cleanup.extend(h.body)
                for sub in ast.walk(ast.Module(body=cleanup,
                                               type_ignores=[])):
                    if self._call_attr(sub) in _RELEASE_ATTRS and any(
                            isinstance(n, ast.Name)
                            and self._loop_src.get(n.id, n.id) in held
                            for a in sub.args for n in ast.walk(a)):
                        return True
            cur = self._parents.get(cur)
        return False

    def _find_acquire(self, expr: ast.AST) -> Optional[ast.Call]:
        for node in ast.walk(expr):
            if isinstance(node, ast.Call) and self._is_acquire(node):
                return node
        return None

    def _simple_target(self, targets) -> Optional[str]:
        if len(targets) == 1 and isinstance(targets[0], ast.Name):
            return targets[0].id
        return None

    def _incref_tracked_name(self, call: ast.Call) -> Optional[str]:
        if call.args and isinstance(call.args[0], ast.Name):
            var = call.args[0].id
            # incref(b) in `for b in blocks:` really acquires into `blocks`
            return self._loop_src.get(var, var)
        return None

    def _release_names_in(self, expr: ast.AST, state: _FnState) -> None:
        # decref(b) inside `for b in blocks:` releases `blocks` itself
        for n in ast.walk(expr):
            if isinstance(n, ast.Name):
                name = self._loop_src.get(n.id, n.id)
                if name in state.held:
                    del state.held[name]

    def _mark_escapes_in(self, expr: ast.AST, state: _FnState) -> None:
        for n in ast.walk(expr):
            if isinstance(n, ast.Name) and n.id in state.held:
                del state.held[n.id]


# ---------------------------------------------------------------------------
# demote-guard
# ---------------------------------------------------------------------------


@rule
class DemoteGuardRule(Rule):
    id = "demote-guard"
    family = "serving"
    description = (
        "A store's demote_hook must only fire after the seated guard: "
        "the hook gathers an evicted prefix's KV back out of the pool, "
        "which is only trustworthy while the blocks are still "
        "referenced.  Any demote_hook(...) call needs a preceding "
        "seated-check (raise PrefixSeatedError / a *seated* call) in the "
        "same function.")

    def applies_to(self, path: str) -> bool:
        return _is_serving_target(path)

    def check(self, mod: Module) -> Iterable[Finding]:
        for fn in ast.walk(mod.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            hook_calls = [
                n for n in ast.walk(fn)
                if isinstance(n, ast.Call)
                and isinstance(n.func, ast.Attribute)
                and n.func.attr == "demote_hook"
            ]
            if not hook_calls:
                continue
            guard_lines = []
            for n in ast.walk(fn):
                if isinstance(n, ast.Raise) and n.exc is not None and \
                        "PrefixSeatedError" in ast.dump(n.exc):
                    guard_lines.append(n.lineno)
                elif isinstance(n, ast.Call):
                    name = dotted(n.func)
                    if "seated" in name.split(".")[-1].lower():
                        guard_lines.append(n.lineno)
            for call in hook_calls:
                if not any(g < call.lineno for g in guard_lines):
                    yield mod.finding(
                        self.id, call,
                        "demote_hook() invoked without a preceding seated "
                        "guard — an evicted-but-seated prefix would gather "
                        "KV out of blocks another slot may rewrite")


# ---------------------------------------------------------------------------
# state-machine
# ---------------------------------------------------------------------------

# scheduler methods that move a request between stages; each must declare
# its move through the _transition() hook so the static table check and
# the runtime sanitizer see the same edges
_TRANSITION_METHODS = ("submit", "park", "wake", "admit", "preempt", "finish")


@rule
class StateMachineRule(Rule):
    id = "state-machine"
    family = "serving"
    description = (
        "Scheduler stage moves must follow the machine-readable "
        "STAGES/LEGAL_TRANSITIONS table: every _transition(src, dst) "
        "call site must name a legal edge, and every stage-moving method "
        "(submit/park/wake/admit/preempt/finish) must record its move "
        "through _transition() so the REPRO_SANITIZE runtime check sees "
        "the same machine the linter does.")

    def applies_to(self, path: str) -> bool:
        return Path(path).name == "scheduler.py"

    def check(self, mod: Module) -> Iterable[Finding]:
        stages, table, table_node = None, None, None
        for node in mod.tree.body:
            if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                    isinstance(node.targets[0], ast.Name):
                name = node.targets[0].id
                try:
                    if name == "STAGES":
                        stages = ast.literal_eval(node.value)
                    elif name == "LEGAL_TRANSITIONS":
                        table = ast.literal_eval(node.value)
                        table_node = node
                except (ValueError, SyntaxError):
                    yield mod.finding(
                        self.id, node,
                        f"{name} must be a pure literal the linter can "
                        "evaluate (no computed values)")
                    return
        sched = next((n for n in ast.walk(mod.tree)
                      if isinstance(n, ast.ClassDef)
                      and n.name == "Scheduler"), None)
        if sched is None:
            return
        if stages is None or table is None:
            yield mod.finding(
                self.id, sched,
                "scheduler.py must declare module-level STAGES and "
                "LEGAL_TRANSITIONS literals — the machine-readable stage "
                "table the linter and the runtime sanitizer both check")
            return
        table = {tuple(t) for t in table}
        for src, dst in sorted(table):
            if src not in stages or dst not in stages:
                yield mod.finding(
                    self.id, table_node,
                    f"transition ({src!r}, {dst!r}) names a stage missing "
                    f"from STAGES {tuple(stages)}")
        # every _transition("a", "b") literal pair must be a legal edge
        methods = {n.name: n for n in sched.body
                   if isinstance(n, ast.FunctionDef)}
        for fn in methods.values():
            for node in ast.walk(fn):
                if not (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr == "_transition"):
                    continue
                lits = [a.value for a in node.args
                        if isinstance(a, ast.Constant)
                        and isinstance(a.value, str)]
                if len(lits) < 2:
                    yield mod.finding(
                        self.id, node,
                        "_transition() must name its (src, dst) stages as "
                        "string literals so the edge is statically "
                        "checkable")
                    continue
                src, dst = lits[0], lits[1]
                if (src, dst) not in table:
                    yield mod.finding(
                        self.id, node,
                        f"illegal stage transition ({src!r} -> {dst!r}) — "
                        "not an edge in LEGAL_TRANSITIONS")
        # every stage-moving method must record its move
        for name in _TRANSITION_METHODS:
            fn = methods.get(name)
            if fn is None:
                continue
            has = any(isinstance(n, ast.Call)
                      and isinstance(n.func, ast.Attribute)
                      and n.func.attr == "_transition"
                      for n in ast.walk(fn))
            if not has:
                yield mod.finding(
                    self.id, fn,
                    f"Scheduler.{name}() moves requests between stages "
                    "but never records the move via _transition() — the "
                    "sanitizer and the linter cannot see this edge")


# ---------------------------------------------------------------------------
# span-pairing
# ---------------------------------------------------------------------------

_SPAN_BEGIN = "begin_async"
_SPAN_END = "end_async"

#: mirror of ``repro.serving.telemetry.REQUIRED_SPANS`` — duplicated as a
#: literal so the linter stays stdlib-only with no src/ import; a test in
#: tests/test_reprolint.py cross-validates the two tuples.
_REQUIRED_SPANS = ("admission", "waiting_on_prefix", "compile_chunk",
                   "promote_chunk", "preempt", "resume", "decode_step")


@rule
class SpanPairingRule(Rule):
    id = "span-pairing"
    family = "serving"
    description = (
        "Every Tracer async span begin (begin_async) must have a "
        "matching end_async: span names must be string literals drawn "
        "from the REQUIRED_SPANS taxonomy, every begin name needs an end "
        "somewhere in the module (cross-function park/wake pairing is "
        "legal), and when a function contains both the begin and the "
        "end, the end must cover every exit path — an early return or "
        "uncovered raise leaves the span open forever in the trace.")

    def applies_to(self, path: str) -> bool:
        return Path(path).parent.name in ("serving", "telemetry")

    # ---- collection ----

    @staticmethod
    def _span_calls(tree) -> List[Tuple[str, ast.Call]]:
        """All (kind, call) tracer async-span call sites in ``tree``."""
        out = []
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr in (_SPAN_BEGIN, _SPAN_END):
                out.append((node.func.attr, node))
        return out

    @staticmethod
    def _literal_name(call: ast.Call) -> Optional[str]:
        """The span-name argument (track, name, aid, ...) as a string
        literal, or None when dynamic."""
        if len(call.args) >= 2 and isinstance(call.args[1], ast.Constant) \
                and isinstance(call.args[1].value, str):
            return call.args[1].value
        return None

    def check(self, mod: Module) -> Iterable[Finding]:
        calls = self._span_calls(mod.tree)
        if not calls:
            return ()
        parents: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(mod.tree):
            for child in ast.iter_child_nodes(node):
                parents[child] = node
        self._parents = parents
        self._mod = mod
        self._findings: List[Finding] = []

        begins: Dict[str, List[ast.Call]] = {}
        ends: Dict[str, List[ast.Call]] = {}
        for kind, call in calls:
            name = self._literal_name(call)
            if name is None:
                self._findings.append(mod.finding(
                    self.id, call,
                    f"{call.func.attr}() must name its span as a string "
                    "literal so begin/end pairing is statically checkable"))
                continue
            if name not in _REQUIRED_SPANS:
                self._findings.append(mod.finding(
                    self.id, call,
                    f"async span name {name!r} is not in the REQUIRED_SPANS "
                    f"taxonomy {_REQUIRED_SPANS} — extend the taxonomy in "
                    "telemetry.py (and this rule's mirror) or reuse an "
                    "existing phase name"))
            (begins if kind == _SPAN_BEGIN else ends).setdefault(
                name, []).append(call)

        # module-level pairing: cross-function begin/end is legal (the
        # engine parks in _submit and wakes in the drain methods), but a
        # name begun with no end anywhere — or vice versa — can never pair
        for name, sites in sorted(begins.items()):
            if name not in ends:
                for call in sites:
                    self._findings.append(mod.finding(
                        self.id, call,
                        f"begin_async({name!r}) has no matching "
                        "end_async anywhere in this module — the span "
                        "stays open forever in the trace"))
        for name, sites in sorted(ends.items()):
            if name not in begins:
                for call in sites:
                    self._findings.append(mod.finding(
                        self.id, call,
                        f"end_async({name!r}) has no matching "
                        "begin_async anywhere in this module — the end "
                        "event can never pair"))

        # intra-function exit-path coverage: when one function holds both
        # the begin and the end of a name, the end must be reached on
        # every exit path after the begin
        for fn in ast.walk(mod.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._check_function(fn)
        return self._findings

    # ---- the walk (same shape as the refcount may-leak analysis) ----

    def _check_function(self, fn) -> None:
        fn_begins: Set[str] = set()
        fn_ends: Set[str] = set()
        for kind, call in self._span_calls(fn):
            name = self._literal_name(call)
            if name is None:
                continue
            (fn_begins if kind == _SPAN_BEGIN else fn_ends).add(name)
        # names begun here but ended elsewhere pair cross-function; only
        # same-function pairs get the all-exit-paths obligation
        self._tracked = fn_begins & fn_ends
        if not self._tracked:
            return
        open_spans: Dict[str, int] = {}
        self._span_walk(fn.body, open_spans)
        self._flag_open(open_spans,
                        fn.body[-1].lineno if fn.body else fn.lineno,
                        "function exit", covered=frozenset())

    def _flag_open(self, open_spans: Dict[str, int], line: int,
                   where: str, covered: frozenset) -> None:
        for name, begin_line in sorted(open_spans.items()):
            if name in covered:
                continue
            self._findings.append(self._mod.finding(
                self.id, line,
                f"async span {name!r} opened at line {begin_line} is "
                f"still open at {where} — call end_async on this path or "
                "move the end into a finally"))
        open_spans.clear()

    def _span_walk(self, stmts: List[ast.stmt],
                   open_spans: Dict[str, int]) -> bool:
        """Walk a statement list; returns False when the block always
        terminates (return/raise) before falling through."""
        for stmt in stmts:
            if isinstance(stmt, (ast.Return, ast.Raise)):
                where = "return" if isinstance(stmt, ast.Return) else "raise"
                self._flag_open(open_spans, stmt.lineno, where,
                                self._ended_by_enclosing(stmt))
                return False
            if isinstance(stmt, ast.If):
                s_body, s_else = dict(open_spans), dict(open_spans)
                ft_body = self._span_walk(stmt.body, s_body)
                ft_else = self._span_walk(stmt.orelse, s_else)
                merged: Dict[str, int] = {}
                if ft_body:
                    merged.update(s_body)
                if ft_else:
                    merged.update(s_else)
                open_spans.clear()
                open_spans.update(merged)
                if not ft_body and not ft_else:
                    return False
                continue
            if isinstance(stmt, (ast.For, ast.While)):
                body_state = dict(open_spans)
                self._span_walk(stmt.body, body_state)
                self._span_walk(stmt.orelse, body_state)
                open_spans.clear()
                open_spans.update(body_state)
                continue
            if isinstance(stmt, ast.Try):
                body_state = dict(open_spans)
                ft = self._span_walk(stmt.body, body_state)
                for h in stmt.handlers:
                    self._span_walk(h.body, dict(open_spans))
                if stmt.finalbody:
                    self._span_walk(stmt.finalbody, body_state)
                open_spans.clear()
                open_spans.update(body_state)
                if not ft and not stmt.finalbody:
                    return False
                continue
            if isinstance(stmt, ast.With):
                self._span_walk(stmt.body, open_spans)
                continue
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue  # nested defs analyzed on their own
            for kind, call in self._span_calls(stmt):
                name = self._literal_name(call)
                if name is None or name not in self._tracked:
                    continue
                if kind == _SPAN_END:
                    open_spans.pop(name, None)
                else:
                    open_spans[name] = call.lineno
        return True

    def _ended_by_enclosing(self, node: ast.AST) -> frozenset:
        """Span names a lexically enclosing ``try``'s ``finally`` (or a
        handler) ends — those exit edges are covered."""
        covered: Set[str] = set()
        cur = self._parents.get(node)
        while cur is not None and not isinstance(
                cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if isinstance(cur, ast.Try):
                cleanup: List[ast.stmt] = list(cur.finalbody)
                for h in cur.handlers:
                    cleanup.extend(h.body)
                for kind, call in self._span_calls(
                        ast.Module(body=cleanup, type_ignores=[])):
                    if kind == _SPAN_END:
                        name = self._literal_name(call)
                        if name is not None:
                            covered.add(name)
            cur = self._parents.get(cur)
        return frozenset(covered)
