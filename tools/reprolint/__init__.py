"""reprolint — repo-native static analysis for the MemCom serving stack.

Usage::

    python -m tools.reprolint src tests benchmarks

Three rule families (see docs/LINTS.md for the full catalog):

* ``jax``     — determinism hazards: wall-clock reads outside
  serving/clock.py, global/unseeded RNG, python branches on traced
  values inside jax.jit, host syncs in the decode loop, mutable default
  args, jit over known-static config params.
* ``serving`` — protocol checks: refcount balance over the block
  allocator (all exit paths incl. PrefixSeatedError/OutOfBlocksError
  edges), demote-hook-after-seated-guard, scheduler stage moves against
  the machine-readable LEGAL_TRANSITIONS table.
* ``kernels`` — pallas contracts: CompilerParams only via pltpu_compat,
  BlockSpec index-map arity == grid rank (+ scalar prefetch), every
  public kernel registered with a jnp reference twin.

Importing this package registers every rule; the modules have no
dependencies beyond the stdlib, so the linter runs before (and without)
installing jax.
"""

from . import jax_rules, kernel_rules, serving_rules  # noqa: F401  (register)
from .core import (  # noqa: F401
    Baseline, BaselineError, Finding, Module, RULES, Rule, lint_file,
    lint_source,
)

__all__ = [
    "Baseline", "BaselineError", "Finding", "Module", "RULES", "Rule",
    "lint_file", "lint_source",
]
