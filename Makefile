PYTHON ?= python

.PHONY: lint lint-rules test test-sanitize baseline

lint:
	$(PYTHON) -m tools.reprolint src tests benchmarks

lint-rules:
	$(PYTHON) -m tools.reprolint --list-rules

test:
	PYTHONPATH=src $(PYTHON) -m pytest -x -q

test-sanitize:
	REPRO_SANITIZE=1 PYTHONPATH=src $(PYTHON) -m pytest -x -q

# Regenerate the grandfathered-findings baseline.  Every new entry is
# written with a TODO justification you must replace by hand — the
# loader (and CI) rejects unjustified entries.
baseline:
	$(PYTHON) -m tools.reprolint src tests benchmarks --update-baseline
