#!/usr/bin/env bash
# The exact lint invocation CI's static-analysis job runs.  Stdlib-only:
# works before any dependency install.
set -euo pipefail
cd "$(dirname "$0")/.."
python -m tools.reprolint src tests benchmarks "$@"
