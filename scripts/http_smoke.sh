#!/usr/bin/env bash
# HTTP telemetry-plane smoke: start the launcher with --http-port 0
# (ephemeral) and --http-linger, parse the bound port from its stdout,
# curl /metrics and /healthz, and schema-validate the /debug/trace dump
# with benchmarks/validate_trace.py.  Used by CI's `tests` job; runnable
# locally the same way:
#
#   PYTHONPATH=src scripts/http_smoke.sh
set -euo pipefail

OUT=${BENCH_ROOT:-artifacts/bench}
LOG=$(mktemp /tmp/http-smoke.XXXXXX.log)
mkdir -p "$OUT"

PYTHONPATH=${PYTHONPATH:-src} python -m repro.launch.serve \
    --arch smollm-135m --smoke \
    --traffic zipf --priority-classes 2 --traffic-requests 24 \
    --traffic-tasks 6 --traffic-rate 300 --context-tokens 24 --slots 2 \
    --prefix-capacity 2 --host-capacity 2 \
    --compile-budget 8 --promote-budget 1 --priority-aging 0.05 \
    --http-port 0 --http-linger 60 >"$LOG" 2>&1 &
PID=$!
trap 'kill $PID 2>/dev/null || true' EXIT

# the launcher prints "[edge] http telemetry on 127.0.0.1:PORT (...)"
# as soon as the server binds — before the traffic run starts
PORT=""
for _ in $(seq 1 120); do
    PORT=$(sed -n 's/^\[edge\] http telemetry on 127\.0\.0\.1:\([0-9]*\).*/\1/p' "$LOG" | head -1)
    [ -n "$PORT" ] && break
    kill -0 $PID 2>/dev/null || { echo "launcher died:"; cat "$LOG"; exit 1; }
    sleep 1
done
[ -n "$PORT" ] && echo "http_smoke: telemetry plane on port $PORT" || {
    echo "http_smoke: no bound-port line in launcher output"; cat "$LOG"; exit 1; }

# wait for the linger window: the run is finished, state is final
until grep -q "http telemetry lingering" "$LOG"; do
    kill -0 $PID 2>/dev/null || { echo "launcher died:"; cat "$LOG"; exit 1; }
    sleep 1
done

METRICS=$(curl -sf "http://127.0.0.1:$PORT/metrics")
echo "$METRICS" | grep -q "^# TYPE serving_alerts_total counter" || {
    echo "http_smoke: /metrics missing serving_alerts_total"; exit 1; }
echo "$METRICS" | grep -q "serving_engine_decode_steps" || {
    echo "http_smoke: /metrics missing engine counters"; exit 1; }
echo "http_smoke: /metrics OK ($(echo "$METRICS" | wc -l) lines)"

HEALTH=$(curl -sf "http://127.0.0.1:$PORT/healthz")
echo "$HEALTH" | python -c 'import json,sys; d=json.load(sys.stdin); assert d["status"]=="ok" and d["slots"]>0, d; print("http_smoke: /healthz OK —", d["status"])'

curl -sf "http://127.0.0.1:$PORT/debug/state" | python -c 'import json,sys; d=json.load(sys.stdin); assert d["engine"]["decode_steps"]>0, d; print("http_smoke: /debug/state OK")'

curl -sf "http://127.0.0.1:$PORT/debug/trace" > "$OUT/http_trace.json"
PYTHONPATH=${PYTHONPATH:-src} python -m benchmarks.validate_trace "$OUT/http_trace.json"

kill $PID 2>/dev/null || true
wait $PID 2>/dev/null || true
trap - EXIT
echo "http_smoke: PASS"
